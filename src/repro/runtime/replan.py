"""Event-stream replanning: live traffic in, plan updates out.

The paper's central claim is that staying optimal under change means
*re-solving the LP*, not patching the old schedule — the Min/Veeravalli/
Barlas-style heuristics drift or fail outright once the instance moves
(cs/0702066 catalogs the failure modes).  This module is the online half of
that claim: a typed event log describes what changed on the platform, an
:class:`EventStreamReplanner` folds each event into the current
:class:`repro.api.Problem` and re-solves through one
:class:`repro.api.Session`, and subscribers (``session.subscribe``) receive
every updated :class:`repro.api.PlanArtifact` as it lands.

Two replan regimes, chosen per event:

* **warm** — coefficient-only events (:class:`SpeedObserved`) preserve the
  LP's row pattern (the :class:`repro.lpir.PerturbedView` invariant), so the
  previous solve's exit basis seeds the engine's basis-seeded simplex entry
  and the re-solve usually pays zero phase-1 pivots.  A seed the engine
  rejects (the old vertex is no longer feasible) falls back to a cold
  two-phase solve inside the solver — never a wrong answer, only a slower
  one.
* **cold** — structural events (:class:`LoadArrived`,
  :class:`ProcessorDown`, :class:`ProcessorUp`) change the LP's shape, so
  the carried basis is meaningless and is dropped before the solve.

Every replanned artifact carries a ``{"kind": "replan", ...}`` provenance
event recording the trigger, the warm/cold decision, the engine's actual
basis reuse, and the pivot counts — the serving audit trail DESIGN.md §11
specifies.

This supersedes the offline what-if surface on
:class:`repro.runtime.dlt_runner.ChainReplanner` (``replan`` /
``replan_without_stage`` / ``what_if_speeds``): those re-solve hypotheticals
from scratch per call; this consumes an ordered stream and carries solver
state (basis, cache, subscriptions) across solves.
"""

from __future__ import annotations

import dataclasses
import time

from repro.api import Policy, Problem, Session
from repro.obs import metrics as obs_metrics

__all__ = [
    "LoadArrived",
    "ProcessorDown",
    "ProcessorUp",
    "SpeedObserved",
    "EventStreamReplanner",
]


# ---------------- the event vocabulary ----------------


@dataclasses.dataclass(frozen=True)
class LoadArrived:
    """A new divisible load enters the system (structural: adds LP columns
    and rows, so the next solve is cold).  ``deadline`` (optional, absolute
    seconds) is recorded in the replan provenance together with whether the
    re-solved makespan meets it — the LP itself stays a pure makespan
    minimization (the paper's objective)."""

    v_comm: float
    v_comp: float
    release: float = 0.0
    return_ratio: float = 0.0
    deadline: float | None = None


@dataclasses.dataclass(frozen=True)
class ProcessorDown:
    """Processor ``index`` leaves.  Chain: its two incident links fuse
    (rates add in series, latencies sum — the store-and-forward path through
    the hole).  Star: the worker and its private link drop (the master,
    index 0, holds the data and cannot leave).  ``restore_delay`` floors the
    survivors' availability dates (checkpoint-restore time)."""

    index: int
    restore_delay: float = 0.0


@dataclasses.dataclass(frozen=True)
class ProcessorUp:
    """A processor joins at the tail of the chain (or as a new star worker)
    with its own link.  Structural: the next solve is cold."""

    w: float
    z: float
    latency: float = 0.0
    tau: float = 0.0


@dataclasses.dataclass(frozen=True)
class SpeedObserved:
    """Processor ``index`` is measured at ``w`` seconds/unit (straggler
    drift, thermal throttling, a time-shared host changing share — the
    arXiv 1902.01898 regime).  Coefficient-only: the LP row pattern is
    unchanged, so the previous basis warm-starts the re-solve."""

    index: int
    w: float


# events that keep the LP row pattern (and therefore the carried basis) valid
_COEFFICIENT_EVENTS = (SpeedObserved,)


# ---------------- event -> Problem folding ----------------


def _fold(problem: Problem, event) -> Problem:
    """The successor Problem after ``event`` (pure; raises on impossible
    events, e.g. dropping the star master or the last processor)."""
    if isinstance(event, SpeedObserved):
        m = len(problem.w)
        if not 0 <= event.index < m:
            raise ValueError(f"SpeedObserved.index {event.index} out of range [0, {m})")
        w = list(problem.w)
        w[event.index] = float(event.w)
        wpl = problem.w_per_load
        if wpl is not None:
            # unrelated-machine model: a speed observation rescales the whole
            # row (the per-load affinities are relative to the base speed)
            old = problem.w[event.index]
            scale = float(event.w) / old if old else 1.0
            wpl = tuple(
                tuple(v * scale for v in row) if i == event.index else row
                for i, row in enumerate(wpl)
            )
        return _rebuild(problem, w=w, w_per_load=wpl)

    if isinstance(event, LoadArrived):
        if event.deadline is not None and event.deadline < event.release:
            raise ValueError("LoadArrived.deadline precedes its release date")
        wpl = problem.w_per_load
        if wpl is not None:
            # new load's per-processor cost defaults to the base speeds
            wpl = tuple(row + (problem.w[i],) for i, row in enumerate(wpl))
        return _rebuild(
            problem,
            v_comm=problem.v_comm + (float(event.v_comm),),
            v_comp=problem.v_comp + (float(event.v_comp),),
            release=problem.release + (float(event.release),),
            return_ratio=problem.return_ratio + (float(event.return_ratio),),
            w_per_load=wpl,
        )

    if isinstance(event, ProcessorUp):
        wpl = problem.w_per_load
        if wpl is not None:
            wpl = wpl + (tuple(float(event.w) for _ in problem.v_comm),)
        return _rebuild(
            problem,
            w=problem.w + (float(event.w),),
            z=problem.z + (float(event.z),),
            latency=problem.latency + (float(event.latency),),
            tau=problem.tau + (float(event.tau),),
            w_per_load=wpl,
        )

    if isinstance(event, ProcessorDown):
        d, m = event.index, len(problem.w)
        if not 0 <= d < m:
            raise ValueError(f"ProcessorDown.index {d} out of range [0, {m})")
        if m <= 1:
            raise ValueError("cannot drop the last processor")
        z, lat = list(problem.z), list(problem.latency)
        if problem.topology == "star":
            if d == 0:
                raise ValueError("cannot drop the star master (it holds the data)")
            del z[d - 1], lat[d - 1]
        elif d == 0:
            del z[0], lat[0]
        elif d == m - 1:
            del z[-1], lat[-1]
        else:
            # store-and-forward through the hole: rates add in series,
            # latencies sum (Planner.replan_without_stage's link fusion)
            z[d - 1 : d + 1] = [z[d - 1] + z[d]]
            lat[d - 1 : d + 1] = [lat[d - 1] + lat[d]]
        keep = [i for i in range(m) if i != d]
        tau = [max(problem.tau[i], float(event.restore_delay)) for i in keep]
        wpl = problem.w_per_load
        if wpl is not None:
            wpl = tuple(wpl[i] for i in keep)
        return _rebuild(
            problem,
            w=[problem.w[i] for i in keep],
            z=z, latency=lat, tau=tau, w_per_load=wpl,
        )

    raise TypeError(f"unknown replan event {type(event).__name__}")


def _rebuild(problem: Problem, **changes) -> Problem:
    kw = dict(
        w=problem.w, z=problem.z, v_comm=problem.v_comm, v_comp=problem.v_comp,
        topology=problem.topology, tau=problem.tau, latency=problem.latency,
        release=problem.release, return_ratio=problem.return_ratio,
        w_per_load=problem.w_per_load,
    )
    kw.update(changes)
    return Problem(**kw)


# ---------------- the replanner ----------------


class EventStreamReplanner:
    """Fold a live event stream into successive LP re-solves.

    One replanner tracks one evolving problem through one session.  Each
    :meth:`apply` folds the event into the current problem, re-solves —
    warm-started from the previous exit basis when the event preserves the
    LP row pattern and ``warm=True`` — and publishes the artifact to the
    attached :class:`repro.api.PlanSubscription` (created via
    ``session.subscribe`` when not handed in).

    The carried basis is pure data riding the artifacts
    (``telemetry["lp"]["final_basis"]``): the replanner owns no solver
    state, so it serializes/restarts trivially — rebuild it from the last
    artifact and keep consuming the stream.

    **Debouncing** (``debounce_window``, seconds): an observation storm —
    hundreds of :class:`SpeedObserved` ticks from a jittery monitor — would
    otherwise pay one full re-solve per tick.  With a window, coefficient
    events *fold immediately* (``self.problem`` always reflects every event
    seen) but the re-solve is deferred: the first buffered event opens a
    window, later events within it coalesce, and the solve fires at the
    first event on-or-after the window edge — one solve per window, however
    dense the storm (regression-tested).  There is no background thread
    (the Session deadline convention): a burst that simply *stops* inside
    its window re-solves at the next :meth:`apply`, :meth:`flush`, or
    :meth:`close`.  Structural events are never deferred — they flush any
    buffered folds into their own (cold) solve, so event ordering holds.
    ``clock`` is injectable for deterministic tests.
    """

    def __init__(
        self,
        session: Session,
        problem: Problem,
        policy: Policy | None = None,
        *,
        warm: bool = True,
        backend=None,
        subscription=None,
        solve_initial: bool = True,
        debounce_window: float | None = None,
        clock=time.monotonic,
    ):
        if debounce_window is not None and debounce_window <= 0:
            raise ValueError("debounce_window must be > 0 (or None to disable)")
        self.session = session
        self.policy = policy if policy is not None else session.policy
        self.warm = warm
        self.backend = backend
        self.problem = problem
        self.artifact = None
        self._basis = None
        self.events: list = []  # the applied log, in order
        self.debounce_window = debounce_window
        self._clock = clock
        self._buffered: list = []  # folded-but-unsolved coefficient events
        self._window_deadline: float | None = None
        self.solve_count = 0  # re-solves actually dispatched (storm tests)
        if solve_initial:
            self.artifact = session.solve(problem, self.policy, backend=backend)
            self._basis = self._extract_basis(self.artifact)
        self.subscription = (
            subscription
            if subscription is not None
            else session.subscribe(problem, self.policy, backend=backend,
                                   artifact=self.artifact)
        )

    @staticmethod
    def _extract_basis(artifact):
        """The engine exit basis riding ``artifact`` (None when absent —
        serial backends, failed solves, v1 documents)."""
        telem = getattr(artifact, "telemetry", None)
        if not telem:
            return None
        return (telem.get("lp") or {}).get("final_basis")

    def apply(self, event):
        """Fold one event; re-solve now or coalesce it into the open window.

        Returns the newest artifact: the freshly re-solved one, or — when
        the event was debounced into an open window — the current plan
        (``self.problem`` is already ahead of it; the solve lands at the
        window edge).
        """
        self.problem = _fold(self.problem, event)
        self.events.append(event)
        if self.debounce_window is not None and isinstance(
                event, _COEFFICIENT_EVENTS):
            self._buffered.append(event)
            now = self._clock()
            if self._window_deadline is None:
                self._window_deadline = now + self.debounce_window
            if now < self._window_deadline:
                obs_metrics.get_registry().inc(
                    "repro_replan_coalesced_total",
                    trigger=type(event).__name__)
                return self.artifact
            return self._solve_buffered()
        # structural (or undebounced) path: buffered folds ride along in
        # this solve — one re-solve covers the whole backlog plus the event
        coalesced, self._buffered = self._buffered, []
        self._window_deadline = None
        return self._resolve(event, len(coalesced))

    def flush(self):
        """Force the deferred re-solve of any buffered events now.

        A no-op (returning the current artifact) when nothing is buffered;
        call it when a storm went quiet mid-window and the fresher plan is
        wanted before the next event arrives.
        """
        if not self._buffered:
            return self.artifact
        return self._solve_buffered()

    def _solve_buffered(self):
        batch, self._buffered = self._buffered, []
        self._window_deadline = None
        return self._resolve(batch[-1], len(batch) - 1)

    def _resolve(self, event, n_coalesced: int):
        """One actual re-solve, triggered by ``event`` (with ``n_coalesced``
        earlier events folded into the same LP); publishes the artifact."""
        trigger = type(event).__name__
        structural = not isinstance(event, _COEFFICIENT_EVENTS)
        seed = None if (structural or not self.warm) else self._basis
        self.solve_count += 1
        art = self.session.solve(
            self.problem, self.policy, backend=self.backend, warm_basis=seed,
        )

        telem = getattr(art, "telemetry", None) or {}
        lp = telem.get("lp") or {}
        # cache hits carry no exit basis; the coefficients are (quantized-)
        # identical to the solve that populated the slot, so the basis we
        # already hold stays valid for the NEXT perturbation.  Structural
        # events invalidate it regardless of how this solve was served.
        new_basis = lp.get("final_basis")
        if new_basis is not None:
            self._basis = new_basis
        elif structural:
            self._basis = None

        provenance = {
            "kind": "replan",
            "trigger": trigger,
            "warm_requested": seed is not None,
            "warm": bool(lp.get("warm", False)),
            "cache_hit": bool(art.cache_hit),
            "pivots_phase1": lp.get("pivots_phase1"),
            "pivots_phase2": lp.get("pivots_phase2"),
        }
        if n_coalesced:
            # debounce provenance: this solve answered a whole burst
            provenance["coalesced"] = int(n_coalesced)
        if isinstance(event, LoadArrived) and event.deadline is not None:
            provenance["deadline"] = float(event.deadline)
            provenance["deadline_met"] = bool(art.ok and art.makespan <= event.deadline)
        if art.version >= 2:
            art = dataclasses.replace(art, events=art.events + (provenance,))

        self.artifact = art
        met = obs_metrics.get_registry()
        met.inc("repro_replan_events_total", trigger=trigger,
                warm=str(provenance["warm"]).lower())
        self.subscription.publish(art, problem=self.problem)
        return art

    def replay(self, events) -> list:
        """Apply an ordered event batch; returns the artifacts, one per event."""
        return [self.apply(ev) for ev in events]

    def close(self) -> None:
        """Flush any buffered (debounced) events, then end the feed."""
        self.flush()
        self.subscription.close()
