"""Sharding rules: PartitionSpec trees for params, optimizer state, batches,
caches (DESIGN.md §4).

Conventions (mesh axes: optional 'pod', 'data', 'model'):
  * weights [.., d_in, d_out]:  d_in over 'data' (FSDP/ZeRO-3), d_out over
    'model' (TP) — flipped for down/output projections so TP contracts;
  * expert weights [E, D, F]: E over 'data' (expert parallelism), F over
    'model' — token routing crosses 'data', expert-TP crosses 'model';
  * embeddings [V, D]: V over 'model', D over 'data';
  * activations: batch over ('pod','data'); attention is sequence-sharded
    over 'model' (constraint calls inside the model code);
  * KV caches: sequence over 'model' (split-KV decode), batch over dp;
  * optimizer moments inherit their parameter's spec (ZeRO).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig, ShardingPolicy
from repro.models.layers import fix_spec

__all__ = ["param_specs", "batch_specs", "cache_specs", "shardings_for", "named"]

DP = ("pod", "data")


def _rule(path_keys: tuple, shape: tuple, policy: ShardingPolicy) -> P:
    """Spec for one parameter leaf, keyed on its tree path + rank."""
    name = path_keys[-1]
    # ZeRO/FSDP shards over BOTH dp axes — on the multi-pod mesh the pod axis
    # must not replicate optimizer state (1T-param configs double otherwise);
    # fix_spec drops 'pod' on single-pod meshes
    d = ("pod", "data") if policy.fsdp_params else None
    m = policy.model_axis
    nd = len(shape)

    # --- embeddings / heads ---
    if name == "embed":
        if nd == 3:  # audio [K,V,D]
            return P(None, m, d)
        return P(m, d)
    if name == "heads":  # audio [K,D,V]
        return P(None, d, m)
    if name == "head":  # [D,V]
        return P(d, m)
    if name == "patch_proj":
        return P(None, d)
    # --- MoE ---
    # expert dim joins the pod axis too (ZeRO across pods; 384/32=12 etc.)
    e_ax = ("pod", policy.expert_axis) if policy.expert_axis == "data" else policy.expert_axis
    if "moe" in path_keys and name in ("w_gate", "w_up") and nd == 4:  # [L,E,D,F]
        return P(None, e_ax, None, policy.expert_ff_axis)
    if "moe" in path_keys and name == "w_down" and nd == 4:  # [L,E,F,D]
        return P(None, e_ax, policy.expert_ff_axis, None)
    if name == "router":  # [L,D,E]
        return P(None, d, None)
    # --- MLA ---
    if name in ("w_dkv", "w_kr"):  # [L,D,r]
        return P(None, d, None)
    if name in ("w_uk", "w_uv"):  # [L,r,H*dh]
        return P(None, None, m)
    # --- mamba ---
    if name in ("w_z", "w_xbc"):  # [L,D,d_in] / [L,D,conv_dim]
        return P(None, d, m)
    if name == "w_dt":  # [L,D,H] — H (e.g. 50) rarely mesh-divisible; tiny
        return P(None, d, None)
    if name == "conv_w":  # [L,k,C]
        return P(None, None, m)
    if name in ("A_log", "D", "dt_bias"):  # [L,H]
        return P(None, None)
    if name == "norm_w":  # [L,d_inner]
        return P(None, m)
    if name == "w_out":  # [L,d_inner,D]
        return P(None, m, d)
    # --- attention / MLP ---
    if name in ("w_q", "w_k", "w_v", "w_gate", "w_up"):  # [L,D,X] or [D,X]
        return P(*([None] * (nd - 2)), d, m)
    if name in ("w_o", "w_down"):  # [L,X,D]
        return P(*([None] * (nd - 2)), m, d)
    if name == "w":  # generic linear
        return P(*([None] * (nd - 2)), d, m)
    # --- norms & scalars ---
    return P(*([None] * nd))


def param_specs(shape_tree, policy: ShardingPolicy | None = None):
    """PartitionSpec tree matching a parameter (or optimizer moment) tree."""
    policy = policy or ShardingPolicy()

    def make(path, leaf):
        keys = tuple(
            p.key if isinstance(p, jax.tree_util.DictKey) else getattr(p, "name", str(p))
            for p in path
        )
        return _rule(keys, leaf.shape, policy)

    return jax.tree_util.tree_map_with_path(make, shape_tree)


def batch_specs(cfg: ArchConfig, policy: ShardingPolicy | None = None, batch_size: int | None = None):
    """Specs for a train/prefill batch dict."""
    policy = policy or ShardingPolicy()
    dp = DP
    if batch_size is not None and batch_size == 1:
        dp = None  # single-stream decode cannot shard batch
    spec = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "audio":
        spec = {"tokens": P(dp, None, None), "labels": P(dp, None, None)}
    if cfg.family == "vlm":
        spec["patches"] = P(dp, None, None)
    return spec


def cache_specs(cfg: ArchConfig, policy: ShardingPolicy | None = None,
                batch_size: int | None = None, model_divisor: int | None = None):
    """Specs for the decode cache tree (layer-stacked).

    ``model_divisor``: the model-axis size when the cache is a jit *argument*
    (arguments must divide exactly; internal constraints merely pad).  When the
    SSM head count doesn't divide it, the head_dim axis is sharded instead
    (every assigned head_dim is a multiple of 16).
    """
    policy = policy or ShardingPolicy()
    m = policy.model_axis
    dp = DP if (batch_size is None or batch_size > 1) else None
    c: dict = {}
    if cfg.has_attention:
        if cfg.mla is not None:
            c["mla"] = {
                "c_kv": P(None, dp, m, None),  # [L,B,S,r] seq over model
                "k_pe": P(None, dp, m, None),
            }
        else:
            c["k"] = P(None, dp, m, None, None)  # [L,B,S,KVH,hd]
            c["v"] = P(None, dp, m, None, None)
            if policy.kv_cache_dtype == "int8":
                c["k_scale"] = P(None, dp, m, None)  # [L,B,S,KVH]
                c["v_scale"] = P(None, dp, m, None)
    if cfg.has_ssm:
        h = cfg.ssm.n_heads(cfg.d_model)
        heads_ok = model_divisor is None or h % model_divisor == 0
        c["ssm"] = {
            "conv": P(None, dp, None, m),  # [L,B,k-1,C]
            # [L,B,H,P,N]: heads over model when divisible, else head_dim
            "state": (
                P(None, dp, m, None if dp else "data", None)
                if heads_ok else P(None, dp, None, m, None)
            ),
        }
    return c


def named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (dropping absent axes)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, fix_spec(mesh, s)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def shardings_for(mesh, cfg: ArchConfig, policy: ShardingPolicy, shape_tree):
    """NamedSharding tree for a parameter tree on ``mesh``."""
    return named(mesh, param_specs(shape_tree, policy))
