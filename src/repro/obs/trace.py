"""Span tracer: nested, thread-safe, Chrome-trace/Perfetto exportable.

Design constraints (DESIGN.md §8):

* **near-zero overhead when disabled** — library call sites use the
  module-level :func:`span` free function; when no tracer is active it
  returns one shared no-op singleton, so the hot path costs one global
  read and one identity return (no allocation, asserted by
  tests/test_obs.py);
* **balanced under exceptions** — a span records at ``__exit__`` whatever
  propagates through it, tagging the event with the exception class, so
  traces of failing runs still close every span;
* **thread-safe** — events append under a lock; the recording thread id
  becomes the Chrome-trace ``tid`` so per-thread lanes nest correctly;
* **exportable** — ``to_chrome_trace()`` emits the Trace Event Format
  (``ph: "X"`` complete events, microsecond timestamps) that
  ``chrome://tracing`` and Perfetto load directly; ``save(path)`` writes
  it as JSON.

Nesting needs no explicit bookkeeping: complete events nest by timestamp
containment per thread, which the context-manager discipline guarantees.
"""

from __future__ import annotations

import json
import threading
import time

__all__ = ["Tracer", "span", "activate", "get_tracer", "NOOP_SPAN"]


class _NoopSpan:
    """The shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class _Span:
    """One live span; records itself on ``__exit__`` (always, even when an
    exception is propagating — the event is tagged with the class name)."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter_ns() - self._t0
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self._tracer._record(self.name, self._t0, dur, self.args)
        return False

    def set(self, **attrs):
        """Attach attributes to the span mid-flight (shown as Chrome args)."""
        self.args.update(attrs)
        return self


class Tracer:
    """Collects spans; export with :meth:`to_chrome_trace` / :meth:`save`."""

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self._lock = threading.Lock()
        self._events: list = []  # (name, t0_ns, dur_ns, tid, args)
        self._epoch_ns = time.perf_counter_ns()

    # ---------------- recording ----------------

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def _record(self, name: str, t0_ns: int, dur_ns: int, args: dict) -> None:
        tid = threading.get_ident()
        with self._lock:
            self._events.append((name, t0_ns, dur_ns, tid, args))

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._epoch_ns = time.perf_counter_ns()

    def __len__(self) -> int:
        return len(self._events)

    # ---------------- inspection ----------------

    def events(self) -> list:
        """Recorded events as dicts (name, ts_us, dur_us, tid, args), sorted
        by start time — parents precede their children."""
        with self._lock:
            evs = list(self._events)
        out = [
            {
                "name": name,
                "ts_us": (t0 - self._epoch_ns) / 1e3,
                "dur_us": dur / 1e3,
                "tid": tid,
                "args": dict(args),
            }
            for name, t0, dur, tid, args in evs
        ]
        out.sort(key=lambda e: (e["ts_us"], -e["dur_us"]))
        return out

    def total_us(self, name: str) -> float:
        """Summed duration of every span called ``name`` (microseconds)."""
        return sum(e["dur_us"] for e in self.events() if e["name"] == name)

    # ---------------- export ----------------

    def to_chrome_trace(self) -> dict:
        """The Trace Event Format dict chrome://tracing / Perfetto load."""
        trace_events = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 1,
                "tid": 0,
                "args": {"name": self.process_name},
            }
        ]
        for e in self.events():
            trace_events.append(
                {
                    "name": e["name"],
                    "ph": "X",
                    "ts": e["ts_us"],
                    "dur": e["dur_us"],
                    "pid": 1,
                    "tid": e["tid"],
                    "args": e["args"],
                }
            )
        return {"traceEvents": trace_events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path`` (load it in Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, default=str)
        return path


# --------------------------------------------------------------------------
# the module-level active tracer (what library call sites consult)
# --------------------------------------------------------------------------

_ACTIVE: Tracer | None = None


def activate(tracer: Tracer | None) -> Tracer | None:
    """Install ``tracer`` as the process-wide active tracer (None disables);
    returns the previous one so callers can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    return prev


def get_tracer() -> Tracer | None:
    return _ACTIVE


def span(name: str, **args):
    """A span on the active tracer — or the shared no-op when tracing is off.

    This is the call every instrumented hot path makes; with no active
    tracer it is a global read plus an identity return.
    """
    tr = _ACTIVE
    if tr is None:
        return NOOP_SPAN
    return tr.span(name, **args)
