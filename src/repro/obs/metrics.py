"""Metrics registry: counters / gauges / histograms with label sets.

One documented key schema for the whole stack (DESIGN.md §8) replaces the
historical ad-hoc ``stats()`` dicts.  Names follow the Prometheus
conventions — snake case, ``repro_`` prefix, ``_total`` suffix on
counters, base-unit suffixes (``_seconds``, ``_ratio``); labels carry the
low-cardinality dimensions (backend, topology, status, phase, stage).

* ``snapshot()`` returns a flat ``{rendered_key: value}`` dict with sorted
  keys and sorted labels — two registries that saw the same sequence of
  operations snapshot identically (property-tested), so snapshots can be
  diffed, asserted on, and merged into bench summaries.
* ``prometheus_text()`` emits the text exposition format;
  :func:`start_metrics_server` serves it over HTTP (``serve
  --metrics-port``).
* One process-wide default registry (:func:`get_registry`) is what the
  engine/session/cache instrumentation writes to; ``set_registry`` swaps
  it (tests install a fresh one, overhead probes install a
  :class:`NullRegistry`).

Everything is stdlib-only and lock-protected; a counter bump is two dict
lookups and a float add, so always-on metrics cost <=5% of even the
smallest bucket solve (measured by scripts/traced_smoke.py).
"""

from __future__ import annotations

import threading

__all__ = [
    "MetricsRegistry",
    "NullRegistry",
    "get_registry",
    "set_registry",
    "start_metrics_server",
    "DEFAULT_LATENCY_BUCKETS",
]

# latency-style histogram buckets (seconds): log-ish 1e-5 .. 10, +Inf implicit
DEFAULT_LATENCY_BUCKETS = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0,
)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render(name: str, lk: tuple) -> str:
    if not lk:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in lk) + "}"


def _prom_render(name: str, lk: tuple, extra: tuple = ()) -> str:
    items = lk + extra
    if not items:
        return name
    return name + "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


class _Hist:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: tuple):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, b in enumerate(self.buckets):
            if value <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class MetricsRegistry:
    """Counters, gauges, and histograms keyed by (name, label set)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}  # name -> {labelkey: float}
        self._gauges: dict = {}
        self._hists: dict = {}  # name -> {labelkey: _Hist}
        self._hist_buckets: dict = {}  # name -> buckets tuple

    # ---------------- writes ----------------

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        lk = _label_key(labels)
        with self._lock:
            series = self._counters.setdefault(name, {})
            series[lk] = series.get(lk, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        lk = _label_key(labels)
        with self._lock:
            self._gauges.setdefault(name, {})[lk] = float(value)

    def register_histogram(self, name: str, buckets=DEFAULT_LATENCY_BUCKETS) -> None:
        """Pin the bucket layout for ``name`` (before the first observe)."""
        with self._lock:
            self._hist_buckets[name] = tuple(buckets)

    def observe(self, name: str, value: float, **labels) -> None:
        lk = _label_key(labels)
        with self._lock:
            series = self._hists.setdefault(name, {})
            h = series.get(lk)
            if h is None:
                h = series[lk] = _Hist(
                    self._hist_buckets.get(name, DEFAULT_LATENCY_BUCKETS)
                )
            h.observe(value)

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # ---------------- reads ----------------

    def value(self, name: str, **labels) -> float:
        """Current value of a counter or gauge series (0.0 when unseen)."""
        lk = _label_key(labels)
        with self._lock:
            if name in self._counters:
                return self._counters[name].get(lk, 0.0)
            if name in self._gauges:
                return self._gauges[name].get(lk, 0.0)
        return 0.0

    def snapshot(self) -> dict:
        """Deterministic flat dict of every series, keys and labels sorted.

        Histograms contribute ``name_count{...}``, ``name_sum{...}`` and
        per-bucket ``name_bucket{le=...,...}`` entries.
        """
        out: dict = {}
        with self._lock:
            for name, series in self._counters.items():
                for lk, v in series.items():
                    out[_render(name, lk)] = v
            for name, series in self._gauges.items():
                for lk, v in series.items():
                    out[_render(name, lk)] = v
            for name, series in self._hists.items():
                for lk, h in series.items():
                    out[_render(name + "_count", lk)] = h.count
                    out[_render(name + "_sum", lk)] = h.sum
                    for b, c in zip(h.buckets, h.counts):
                        out[_render(name + "_bucket", lk + (("le", repr(b)),))] = c
                    out[_render(name + "_bucket", lk + (("le", "+Inf"),))] = h.count
        return dict(sorted(out.items()))

    def prometheus_text(self) -> str:
        """The Prometheus text exposition format (served by --metrics-port)."""
        lines: list = []
        with self._lock:
            for name in sorted(self._counters):
                lines.append(f"# TYPE {name} counter")
                for lk in sorted(self._counters[name]):
                    lines.append(
                        f"{_prom_render(name, lk)} {self._counters[name][lk]:g}"
                    )
            for name in sorted(self._gauges):
                lines.append(f"# TYPE {name} gauge")
                for lk in sorted(self._gauges[name]):
                    lines.append(
                        f"{_prom_render(name, lk)} {self._gauges[name][lk]:g}"
                    )
            for name in sorted(self._hists):
                lines.append(f"# TYPE {name} histogram")
                for lk in sorted(self._hists[name]):
                    h = self._hists[name][lk]
                    acc = 0
                    for b, c in zip(h.buckets, h.counts):
                        acc += c
                        lines.append(
                            f"{_prom_render(name + '_bucket', lk, (('le', repr(b)),))} {acc}"
                        )
                    lines.append(
                        f"{_prom_render(name + '_bucket', lk, (('le', '+Inf'),))} {h.count}"
                    )
                    lines.append(f"{_prom_render(name + '_sum', lk)} {h.sum:g}")
                    lines.append(f"{_prom_render(name + '_count', lk)} {h.count}")
        return "\n".join(lines) + "\n"


class NullRegistry(MetricsRegistry):
    """A registry that drops everything — the disabled-metrics baseline for
    overhead measurements (scripts/traced_smoke.py)."""

    def inc(self, name, value=1.0, **labels):  # noqa: D102
        pass

    def set_gauge(self, name, value, **labels):  # noqa: D102
        pass

    def observe(self, name, value, **labels):  # noqa: D102
        pass


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry all instrumentation writes to."""
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the default registry (returns the previous one)."""
    global _REGISTRY
    prev = _REGISTRY
    _REGISTRY = registry
    return prev


def start_metrics_server(port: int, registry: MetricsRegistry | None = None):
    """Serve ``registry.prometheus_text()`` over HTTP on ``port``.

    Returns the ``http.server`` instance (a daemon thread runs it); call
    ``.shutdown()`` to stop.  Any path serves the exposition, so both
    ``/metrics`` scrapes and a browser poke work.
    """
    import http.server

    reg = registry if registry is not None else get_registry()

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — http.server API
            body = reg.prometheus_text().encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # silence per-request stderr noise
            pass

    server = http.server.ThreadingHTTPServer(("", port), Handler)
    t = threading.Thread(target=server.serve_forever, daemon=True,
                         name=f"metrics-server:{port}")
    t.start()
    return server
