"""Flight recorder: tracing + metrics for the scheduling engine (DESIGN.md §8).

Zero-dependency observability threaded through every layer of the solve
pipeline:

* :mod:`repro.obs.trace` — span-based tracer (context-manager spans with
  nesting, thread-safe, near-zero overhead when no tracer is active,
  Chrome-trace/Perfetto JSON export).  ``Session.trace()`` is the usual
  entry point; library code emits spans through the module-level
  :func:`repro.obs.trace.span` free function, which is a no-op singleton
  unless a tracer has been activated.
* :mod:`repro.obs.metrics` — a metrics registry (counters / gauges /
  histograms with label sets) with a deterministic ``snapshot()`` dict and
  Prometheus-text exposition.  One process-wide default registry
  (:func:`repro.obs.metrics.get_registry`) collects the engine's cache,
  fallback, simplex, and latency metrics; swap it with ``set_registry``
  for isolation in tests.

Nothing in here imports JAX, numpy, or anything outside the stdlib — the
flight recorder must be importable (and near-free) everywhere, including
the serial-only paths.
"""

from .metrics import (MetricsRegistry, NullRegistry, get_registry,
                      set_registry, start_metrics_server)
from .trace import Tracer, activate, get_tracer, span

__all__ = [
    "MetricsRegistry",
    "NullRegistry",
    "get_registry",
    "set_registry",
    "start_metrics_server",
    "Tracer",
    "activate",
    "get_tracer",
    "span",
]
