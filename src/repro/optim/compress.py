"""Gradient compression for the DCN-crossing (pod) axis.

Two schemes, composable with the trainer:
  * int8 stochastic-free linear quantization with per-tensor scale —
    4x fewer bytes on the pod all-reduce (decompress -> psum -> identical
    math up to quantization noise);
  * top-k sparsification with error feedback (Stich et al.) — the residual
    accumulator carries the unsent mass so the descent direction is unbiased
    over time.

Both are exercised by the DLT chain trainer (pod-axis gradient exchange) and
unit-tested for round-trip / error-feedback invariants.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "int8_compress",
    "int8_decompress",
    "CompressorState",
    "topk_compress_init",
    "topk_compress_update",
]


def int8_compress(x):
    """x fp -> (int8 values, fp32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressorState:
    residual: Any  # error-feedback accumulator, pytree like grads


def topk_compress_init(grads) -> CompressorState:
    return CompressorState(residual=jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads))


def topk_compress_update(grads, state: CompressorState, k_frac: float = 0.05):
    """Returns (sparse grads to transmit, new state).

    The transmitted tensor is dense-shaped but zero outside the top-k entries
    (collectives stay static-shaped; the byte saving on real links comes from
    sending (values, indices) — the dense form keeps SPMD simple and the
    selection math identical).
    """

    def one(g, r):
        acc = r + g.astype(jnp.float32)
        flat = acc.reshape(-1)
        k = max(1, int(flat.shape[0] * k_frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
        sent = (flat * mask).reshape(g.shape)
        new_r = (flat * (1 - mask)).reshape(g.shape)
        return sent, new_r

    flat, treedef = jax.tree.flatten(grads)
    res = treedef.flatten_up_to(state.residual)
    outs = [one(g, r) for g, r in zip(flat, res)]
    sent = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return sent, CompressorState(residual=new_res)
