"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Implemented from scratch (no optax dependency).  Optimizer moments live in a
configurable dtype (fp32 default; bf16 halves the state for the 1T-parameter
configs — see EXPERIMENTS.md memory notes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr", "global_norm"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray  # scalar int32
    m: Any  # pytree like params
    v: Any


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype=state_dtype)
    return AdamWState(
        step=jnp.zeros((), dtype=jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_lr(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    beta1=0.9,
    beta2=0.95,
    eps=1e-8,
    weight_decay=0.1,
    grad_clip=1.0,
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) if grad_clip else 1.0
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1**t
    bc2 = 1.0 - beta2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = beta1 * m32 + (1 - beta1) * g
        v_new = beta2 * v32 + (1 - beta2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {"grad_norm": gnorm}
