"""Optimizer substrate: AdamW, schedules, clipping, gradient compression."""

from .adamw import AdamWState, adamw_init, adamw_update, cosine_lr, global_norm
from .compress import (
    CompressorState,
    int8_compress,
    int8_decompress,
    topk_compress_init,
    topk_compress_update,
)

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "global_norm",
    "CompressorState",
    "int8_compress",
    "int8_decompress",
    "topk_compress_init",
    "topk_compress_update",
]
