"""Kernel micro-bench: Pallas (interpret=True on CPU — a correctness/port
harness, not a wall-clock claim) vs the XLA reference path, plus max-abs-err
against the jnp oracle.  On a real TPU the same harness times the compiled
kernels; here the value is the deltas + the FLOPs bookkeeping.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention

from .common import banner, write_csv


def _t(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main(quick: bool = False) -> dict:
    banner("bench_kernels (Pallas interpret vs XLA vs oracle)")
    rows = []
    key = jax.random.PRNGKey(0)

    cases = [(1, 256, 4, 2, 64)] if quick else [(1, 256, 4, 2, 64), (2, 512, 8, 2, 64)]
    for (B, S, H, KVH, D) in cases:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
        flops = 4 * B * H * S * S * D / 2
        want = ref.flash_attention_ref(q, k, v, causal=True)
        t_pal = _t(lambda q, k, v: ops.flash_attention(q, k, v, interpret=True), q, k, v)
        t_xla = _t(jax.jit(lambda q, k, v: chunked_attention(q, k, v, q_chunk=128, kv_chunk=128)), q, k, v)
        err = float(jnp.abs(ops.flash_attention(q, k, v, interpret=True) - want).max())
        rows.append(["flash_attention", f"{B}x{S}x{H}x{D}", flops, t_pal, t_xla, err])
        print(f"  flash_attention {B}x{S}x{H}x{D}: pallas(interp) {t_pal*1e3:.1f}ms "
              f"xla {t_xla*1e3:.1f}ms  max_err {err:.2e}")

    b, s, h, p, n = 1, 256, 4, 32, 32
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, 1, n), jnp.float32)
    Cm = jax.random.normal(ks[0], (b, s, 1, n), jnp.float32)
    Dm = jnp.ones((h,))
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm, Dm)
    t_pal = _t(lambda *a: ops.ssd_scan(*a, chunk=64, interpret=True), x, dt, A, Bm, Cm, Dm)
    err = float(jnp.abs(ops.ssd_scan(x, dt, A, Bm, Cm, Dm, chunk=64, interpret=True) - want).max())
    rows.append(["ssd_scan", f"{b}x{s}x{h}x{p}x{n}", 0, t_pal, np.nan, err])
    print(f"  ssd_scan {b}x{s}x{h}x{p}: pallas(interp) {t_pal*1e3:.1f}ms  max_err {err:.2e}")

    xw = jax.random.normal(key, (1024, 512), jnp.float32)
    w = jnp.ones((512,))
    want = ref.rms_norm_ref(xw, w)
    err = float(jnp.abs(ops.rms_norm(xw, w, interpret=True) - want).max())
    rows.append(["rms_norm", "1024x512", 0, np.nan, np.nan, err])
    print(f"  rms_norm 1024x512: max_err {err:.2e}")

    write_csv("kernels.csv", rows,
              ["kernel", "shape", "flops", "pallas_interp_s", "xla_s", "max_abs_err"])
    claims = {"kernel_errs_small": all(r[-1] < 1e-3 for r in rows)}
    for k_, v in claims.items():
        print(f"  CLAIM {k_}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
