"""Kernel micro-bench: Pallas (interpret=True on CPU — a correctness/port
harness, not a wall-clock claim) vs the XLA reference path, plus max-abs-err
against the jnp oracle.  On a real TPU the same harness times the compiled
kernels; here the value is the deltas + the FLOPs bookkeeping.

Every row also carries roofline context (benchmarks.roofline.kernel_roofline):
an analytic FLOP count and minimal-HBM-bytes estimate give the arithmetic
intensity and the binding roof — machine-independent columns — next to the
achieved-vs-peak fractions of the measured run (near zero under CPU
interpret, meaningful when the same harness runs compiled on a TPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.models.attention import chunked_attention

from .common import banner, write_csv
from .roofline import kernel_roofline


def _t(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _row(kernel, shape, flops, bytes_moved, t_pal, t_xla, err):
    """One CSV row: timings + the roofline placement of the pallas timing."""
    rl = kernel_roofline(flops, bytes_moved, t_pal if np.isfinite(t_pal) else 0.0)
    return [kernel, shape, flops, bytes_moved, t_pal, t_xla, err,
            rl["intensity_flop_per_byte"], rl["achieved_gflops"],
            rl["peak_frac_compute"], rl["peak_frac_memory"], rl["bottleneck"]]


def _scheduling_rows(quick: bool) -> list:
    """The engine's own hot loops: one fused pivot over a synthetic tableau
    stack, and the fused ASAP replay of an arena-shaped bucket."""
    rows = []
    if not ops.scheduling_kernels_available():
        print("  scheduling kernels unavailable here — skipping their rows")
        return rows
    from jax.experimental import enable_x64

    key = jax.random.PRNGKey(7)
    with enable_x64():
        # simplex_pivot: [B, R, C] stack, rhs kept feasible so the masked
        # pivot does real pricing + elimination work on every element
        B, R, C = (16, 16, 32) if quick else (64, 16, 32)
        ks = jax.random.split(key, 2)
        T = jax.random.normal(ks[0], (B, R, C), jnp.float64)
        T = T.at[:, :-1, -1].set(jnp.abs(T[:, :-1, -1]) + 1.0)
        basis = jnp.tile(jnp.arange(R - 1, dtype=jnp.int32)[None], (B, 1))
        it = jnp.zeros(B, jnp.int32)
        status = jnp.full(B, -1, jnp.int32)

        def pivot(T, basis, it, status):
            return ops.simplex_pivot(T, basis, it, status, ncols_price=C - 1,
                                     bland_after=8, max_iter=4, interpret=True)

        t_piv = _t(pivot, T, basis, it, status)
        got = pivot(T, basis, it, status)[0]
        want = ref.simplex_pivot_ref(T, basis, it, status, ncols_price=C - 1,
                                     bland_after=8, max_iter=4)[0]
        err = float(jnp.abs(got - want).max())
        # elimination is one fma per tableau cell; traffic is one f64
        # read + write of the stack (pricing/ratio columns are minor)
        flops = 2.0 * B * R * C
        bytes_moved = 8.0 * 2 * B * R * C
        rows.append(_row("simplex_pivot", f"{B}x{R}x{C}", flops, bytes_moved,
                         t_piv, np.nan, err))
        print(f"  simplex_pivot {B}x{R}x{C}: pallas(interp) {t_piv*1e3:.1f}ms "
              f"max_err {err:.2e}")

        # asap_replay: an arena-shaped chain bucket (m procs, T cells)
        B, m, T_ = (16, 4, 8) if quick else (64, 4, 8)
        ks = jax.random.split(key, 4)
        w_cell = jnp.abs(jax.random.normal(ks[0], (B, m, T_), jnp.float64)) + 0.1
        z = jnp.abs(jax.random.normal(ks[1], (B, m - 1), jnp.float64)) * 0.1
        latency = jnp.zeros((B, m - 1), jnp.float64)
        tau = jnp.zeros((B, m), jnp.float64)
        vcomm = jnp.ones((B, T_), jnp.float64)
        vcomp = jnp.ones((B, T_), jnp.float64)
        rel = jnp.zeros((B, T_), jnp.float64)
        valid = jnp.ones(T_, bool)
        g = jnp.abs(jax.random.normal(ks[2], (B, m, T_), jnp.float64)) + 0.01
        g = g / g.sum(axis=1, keepdims=True)

        def replay(w_cell, z, latency, tau, vcomm, vcomp, rel, g):
            return ops.asap_replay(w_cell, z, latency, tau, vcomm, vcomp, rel,
                                   valid, g, topology="chain", interpret=True)

        t_rep = _t(replay, w_cell, z, latency, tau, vcomm, vcomp, rel, g)
        got = replay(w_cell, z, latency, tau, vcomm, vcomp, rel, g)[-1]
        want = ref.asap_replay_ref(w_cell, z, latency, tau, vcomm, vcomp, rel,
                                   valid, g, topology="chain")[-1]
        err = float(jnp.abs(got - want).max())
        # the recurrence does ~6 max/fma ops per (proc, cell); traffic is
        # the packed bucket read + the four event planes written back
        flops = 6.0 * B * m * T_
        bytes_moved = 8.0 * B * T_ * (2 * m + 4 + 4 * m)
        rows.append(_row("asap_replay", f"{B}x{m}x{T_}", flops, bytes_moved,
                         t_rep, np.nan, err))
        print(f"  asap_replay {B}x{m}x{T_}: pallas(interp) {t_rep*1e3:.1f}ms "
              f"max_err {err:.2e}")
    return rows


def main(quick: bool = False) -> dict:
    banner("bench_kernels (Pallas interpret vs XLA vs oracle)")
    rows = []
    key = jax.random.PRNGKey(0)

    cases = [(1, 256, 4, 2, 64)] if quick else [(1, 256, 4, 2, 64), (2, 512, 8, 2, 64)]
    for (B, S, H, KVH, D) in cases:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KVH, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KVH, D), jnp.float32)
        flops = 4 * B * H * S * S * D / 2
        # minimal HBM traffic: q + k + v read, attention output written (f32)
        bytes_moved = 4.0 * (2 * B * S * H * D + 2 * B * S * KVH * D)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        t_pal = _t(lambda q, k, v: ops.flash_attention(q, k, v, interpret=True), q, k, v)
        t_xla = _t(jax.jit(lambda q, k, v: chunked_attention(q, k, v, q_chunk=128, kv_chunk=128)), q, k, v)
        err = float(jnp.abs(ops.flash_attention(q, k, v, interpret=True) - want).max())
        rows.append(_row("flash_attention", f"{B}x{S}x{H}x{D}", flops,
                         bytes_moved, t_pal, t_xla, err))
        print(f"  flash_attention {B}x{S}x{H}x{D}: pallas(interp) {t_pal*1e3:.1f}ms "
              f"xla {t_xla*1e3:.1f}ms  max_err {err:.2e}")

    b, s, h, p, n = 1, 256, 4, 32, 32
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, 1, n), jnp.float32)
    Cm = jax.random.normal(ks[0], (b, s, 1, n), jnp.float32)
    Dm = jnp.ones((h,))
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm, Dm)
    t_pal = _t(lambda *a: ops.ssd_scan(*a, chunk=64, interpret=True), x, dt, A, Bm, Cm, Dm)
    err = float(jnp.abs(ops.ssd_scan(x, dt, A, Bm, Cm, Dm, chunk=64, interpret=True) - want).max())
    # state outer-product update + output contraction: 2 fma per (t, h, p, n)
    ssd_flops = 4.0 * b * s * h * p * n
    ssd_bytes = 4.0 * (2 * b * s * h * p + 2 * b * s * n + b * s * h)
    rows.append(_row("ssd_scan", f"{b}x{s}x{h}x{p}x{n}", ssd_flops, ssd_bytes,
                     t_pal, np.nan, err))
    print(f"  ssd_scan {b}x{s}x{h}x{p}: pallas(interp) {t_pal*1e3:.1f}ms  max_err {err:.2e}")

    xw = jax.random.normal(key, (1024, 512), jnp.float32)
    w = jnp.ones((512,))
    want = ref.rms_norm_ref(xw, w)
    t_rms = _t(lambda xw, w: ops.rms_norm(xw, w, interpret=True), xw, w)
    err = float(jnp.abs(ops.rms_norm(xw, w, interpret=True) - want).max())
    rows.append(_row("rms_norm", "1024x512", 3.0 * 1024 * 512,
                     4.0 * (2 * 1024 * 512 + 512), t_rms, np.nan, err))
    print(f"  rms_norm 1024x512: max_err {err:.2e}")

    rows.extend(_scheduling_rows(quick))

    write_csv("kernels.csv", rows,
              ["kernel", "shape", "flops", "bytes", "pallas_interp_s", "xla_s",
               "max_abs_err", "intensity_flop_per_byte", "achieved_gflops",
               "peak_frac_compute", "peak_frac_memory", "bottleneck"])
    claims = {"kernel_errs_small": all(r[6] < 1e-3 for r in rows),
              "scheduling_kernels_benched": any(
                  r[0] in ("simplex_pivot", "asap_replay") for r in rows)}
    for k_, v in claims.items():
        print(f"  CLAIM {k_}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
