"""LP solve-time scaling (the paper's 'polynomial time' claim, §4) and
backend cross-check: our dense revised simplex vs scipy/HiGHS must agree on
the optimal makespan wherever both run.
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import random_instance
from repro.core.lp import build_lp
from repro.core.solver import solve

from .common import banner, timed, write_csv


def main(quick: bool = False) -> dict:
    banner("bench_lp_scaling (§4 LP size / time; simplex vs HiGHS)")
    rng = np.random.default_rng(3)
    rows = []
    agree = total = 0
    grid = [(3, 2, 1), (5, 5, 1), (10, 10, 1), (10, 10, 2)] if quick else [
        (3, 2, 1), (5, 5, 1), (5, 5, 3), (10, 10, 1), (10, 10, 2),
        (10, 25, 1), (10, 50, 1), (10, 50, 2), (10, 25, 6),
    ]
    for m, n, q in grid:
        inst = random_instance(rng, m=m, n_loads=n, q=q, comm_to_comp=1.0)
        lp = build_lp(inst)
        n_rows = len(lp.b_ub) + len(lp.b_eq)
        res_sc, t_sc = timed(solve, inst, backend="scipy")
        t_sx, ms_sx = np.nan, np.nan
        small = lp.n_vars <= 800
        if small:
            res_sx, t_sx = timed(solve, inst, backend="simplex")
            ms_sx = res_sx.makespan
            total += 1
            agree += abs(ms_sx - res_sc.makespan) <= 1e-6 * max(1.0, res_sc.makespan)
        rows.append([m, n, q, lp.n_vars, n_rows, res_sc.makespan, t_sc, ms_sx, t_sx])
        print(f"  m={m:<3} N={n:<3} Q={q}: vars={lp.n_vars:<6} rows={n_rows:<6} "
              f"HiGHS {t_sc*1e3:8.1f}ms" + (f"  simplex {t_sx*1e3:8.1f}ms" if small else ""))
    write_csv("lp_scaling.csv", rows,
              ["m", "n_loads", "q", "n_vars", "n_rows", "makespan",
               "scipy_s", "simplex_makespan", "simplex_s"])
    claims = {"simplex_matches_highs": agree == total and total > 0}
    for k, v in claims.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'} ({agree}/{total})")
    return claims


if __name__ == "__main__":
    main()
