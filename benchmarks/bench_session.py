"""Session front-door throughput: submit -> coalesce -> solve, end to end.

Serving-style traffic — a stream of single-problem submits with no caller-
side batching — for two mixes:

  * chain  — the paper's linear platform (m=3, 2 loads, q=1: the same
    population bench_engine_throughput times, so the "no regression vs the
    direct engine path" claim is apples-to-apples);
  * star   — one-port-master instances with a result-return phase (the
    PR-4 scenario family) through the identical front door.

Measured per mix:

  * ``session`` inst/s — N staggered ``submit()`` calls against a
    ``max_batch=64`` session, resolved by ``result()``: the coalescing
    path the serving tier actually runs (flush count recorded — it must be
    ~N/64, proving the micro-batching happened);
  * ``direct`` inst/s — ``solve_bulk`` on the same backend handle with the
    session layer bypassed: the ceiling.

The front door is bookkeeping around the same vmapped solve, so the
acceptance bar (full scale) is session >= 50% of direct on the chain mix:
a Session-layer regression shows up as the ratio collapsing, while an
engine regression shows up in the direct column AND in
bench_engine_throughput's own >=10x gate (the raw solve_bulk path those
CSVs track — absolute inst/s varies several-fold with box contention, so
cross-run comparisons belong to the speedup ratios, not the raw numbers).
Smoke runs record the ratios informationally (CI boxes make timing noise).

CSV: bench_out/session_throughput.csv.
"""

from __future__ import annotations

import gc
import time

import numpy as np

from repro.core.instance import random_instance

from .common import banner, write_csv

N_CHAIN = 1024
N_STAR = 512
MAX_BATCH = 64


def _mix(rng, n: int, topology: str) -> list:
    from repro.api import Problem

    ret = 0.25 if topology == "star" else 0.0
    return [
        Problem.from_instance(
            random_instance(rng, m=3, n_loads=2, q=1, topology=topology,
                            return_ratio=ret)
        )
        for _ in range(n)
    ]


def _session_throughput(problems: list, policy) -> tuple:
    """(inst/s via staggered submits, flush count) on a fresh session."""
    from repro.api import Session

    warm = Session(policy=policy, max_batch=MAX_BATCH)
    warm.solve_bulk(problems[:MAX_BATCH])  # compile the bucket shapes
    sess = Session(policy=policy, max_batch=MAX_BATCH)
    gc.collect()  # a pending full collection (other benches' garbage) must
    # not land inside the timed submit loop — it reads as dispatch overhead
    t0 = time.perf_counter()
    tickets = [sess.submit(p) for p in problems]
    for t in tickets:
        t.result()
    dt = time.perf_counter() - t0
    return len(problems) / dt, sess.flush_count


def _direct_throughput(problems: list, policy) -> float:
    """inst/s for one solve_bulk on the same backend, session bypassed."""
    from repro.api import Session

    sess = Session(policy=policy)
    sess.solve_bulk(problems)  # warm-up: compile the full-population shapes
    sess = Session(policy=policy)  # fresh cache so the timed run really solves
    gc.collect()
    t0 = time.perf_counter()
    sess.solve_bulk(problems)
    return len(problems) / (time.perf_counter() - t0)


def main(quick: bool = False) -> dict:
    from repro.api import Policy

    banner("bench_session (submit -> coalesce -> solve front door)")
    rng = np.random.default_rng(0)
    policy = Policy(backend="batched")
    rows, claims = [], {}
    ratios = {}
    for mix, n_full in (("chain", N_CHAIN), ("star", N_STAR)):
        n = 128 if quick else n_full
        problems = _mix(rng, n, mix)
        ips, flushes = _session_throughput(problems, policy)
        direct = _direct_throughput(problems, policy)
        ratios[mix] = ips / direct
        expected_flushes = -(-n // MAX_BATCH)  # ceil
        print(f"  {mix:>5}: session {ips:8.1f} inst/s in {flushes} flushes "
              f"(expected <= {expected_flushes + 1})   "
              f"direct {direct:8.1f} inst/s   ratio {ratios[mix]:.2f}")
        rows.append([mix, n, MAX_BATCH, flushes, ips, direct, ratios[mix]])
        # correctness claim at every scale: the coalescer actually batched
        # (result()-driven tail flush allows one extra)
        claims[f"{mix}_coalesced"] = flushes <= expected_flushes + 1
    write_csv(
        "session_throughput.csv",
        rows,
        ["mix", "n", "max_batch", "flushes", "session_inst_per_sec",
         "direct_inst_per_sec", "session_to_direct_ratio"],
    )
    if quick:
        claims["session_to_direct_chain"] = round(ratios["chain"], 2)
        claims["session_to_direct_star"] = round(ratios["star"], 2)
    else:
        # full scale: the front door keeps >= 50% of the raw engine
        # throughput (the direct column is the PR-4 16.9k-inst/s path)
        claims["session_overhead_bounded"] = ratios["chain"] >= 0.5
    for k, v in claims.items():
        if isinstance(v, bool):
            print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
        else:
            print(f"  CLAIM {k} = {v} (informational at smoke scale)")
    return claims


if __name__ == "__main__":
    main()
