"""Roofline report (deliverable g): reads the dry-run JSONs and emits the per
(arch × shape × mesh) table of the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.

Terms (TPU v5e constants, repro.launch.mesh.HW):
  compute    = HLO_FLOPs_per_device / 197e12
  memory     = HLO_bytes_per_device / 819e9
  collective = collective_wire_bytes_per_device / 50e9

"roofline fraction" = compute / max(compute, memory, collective): 1.0 means
the cell is compute-bound (at the roofline); small values mean memory or
collective traffic dominates and sets the achievable MFU ceiling.

Usage: python -m benchmarks.roofline [--dir bench_out/dryrun] [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from .common import OUT_DIR, banner, write_csv

ARCH_ORDER = [
    "phi4-mini-3.8b", "llama3.2-3b", "mistral-large-123b", "minitron-8b",
    "paligemma-3b", "mamba2-2.7b", "deepseek-v2-lite-16b", "kimi-k2-1t-a32b",
    "hymba-1.5b", "musicgen-medium",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def kernel_roofline(flops: float, bytes_moved: float, seconds: float) -> dict:
    """Achieved-vs-peak context for one measured kernel timing.

    The micro-bench counterpart of the dry-run table above: given a kernel's
    FLOP count, its minimal HBM traffic, and a wall-clock measurement, place
    it on the v5e roofline — arithmetic intensity (FLOP/byte), achieved
    GFLOP/s, achieved fraction of the compute and memory roofs, and which
    roof binds at that intensity (compute iff intensity >= the ridge point
    ``PEAK_FLOPS_BF16 / HBM_BW`` ~ 240 FLOP/B).  CPU-interpret timings put
    the achieved fractions near zero — the value there is the intensity and
    bottleneck columns, which are machine-independent.
    """
    from repro.launch.mesh import HW

    intensity = flops / bytes_moved if bytes_moved > 0 else float("inf")
    achieved = flops / seconds if seconds > 0 else 0.0
    ridge = HW.PEAK_FLOPS_BF16 / HW.HBM_BW
    # the memory roof at this intensity: HBM_BW * intensity FLOP/s — the
    # achieved fraction of it equals achieved-bandwidth / peak-bandwidth
    mem_roof = HW.HBM_BW * intensity
    return {
        "intensity_flop_per_byte": intensity,
        "achieved_gflops": achieved / 1e9,
        "peak_frac_compute": achieved / HW.PEAK_FLOPS_BF16,
        "peak_frac_memory": achieved / mem_roof if mem_roof > 0 else 0.0,
        "bottleneck": "compute" if intensity >= ridge else "memory",
    }


def analytic_memory_floor(rec: dict) -> float | None:
    """Minimum HBM bytes per device per step, from first principles.

    The XLA-CPU ``bytes accessed`` is an upper band (CPU fuses less than TPU);
    this floor is the traffic no TPU schedule can avoid:
      train:   params fwd+bwd reads (bf16 x2) + grad write + AdamW moment
               read/write (fp32 m,v) + param write + activation checkpoints
               (one [B,S,D] residual per layer, written + re-read under remat);
      prefill: params read + KV-cache write + per-layer residual stream;
      decode:  params-active read + KV/state-cache read (the classic decode
               memory wall) per generated token.
    """
    from repro.config import SHAPES, get_arch

    try:
        cfg = get_arch(rec["arch"])
    except KeyError:
        return None
    shape = SHAPES[rec["shape"]]
    dev = rec.get("devices", 256)
    from repro.models.flops import param_counts

    pc = param_counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    if shape.kind == "train":
        params_traffic = pc.total * (2 + 2 + 4 + 8 + 8 + 2)  # see docstring
        act = cfg.num_layers * B * S * D * 2 * 2  # ckpt write + re-read (bf16)
        logits = B * S * cfg.padded_vocab * 4 * 2 / max(1, 1)  # fp32 write+read
        return (params_traffic + act + logits) / dev
    kv_bytes = 1 if rec.get("policy", {}).get("kv_cache_dtype") == "int8" else 2
    if shape.kind == "prefill":
        kv = _cache_bytes(cfg, B, S, kv_bytes)
        act = cfg.num_layers * B * S * D * 2
        return (pc.total * 2 + kv + act) / dev
    # decode: one token per stream
    kv = _cache_bytes(cfg, B, S, kv_bytes)
    return (pc.active * 2 + kv) / dev


def _cache_bytes(cfg, B, S, kv_item_bytes: int = 2) -> float:
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    elif cfg.has_attention:
        w = cfg.window if cfg.attn_type == "swa" else 0
        per_tok = cfg.num_kv_heads * cfg.head_dim * 2
        S = min(S, w) if w else S
    else:
        per_tok = 0
    kv = cfg.num_layers * B * S * per_tok * kv_item_bytes
    if cfg.has_ssm:
        ssm = cfg.ssm
        h = ssm.n_heads(cfg.d_model)
        kv += cfg.num_layers * B * h * ssm.head_dim * ssm.d_state * 4
    return kv


def load_records(dry_dir: str, mesh: str) -> list:
    """Designed-sharding records, falling back per cell to the archived
    GSPMD-auto run (dryrun_auto/) tagged ``mesh='<mesh>(auto)'`` so the table
    always covers all 40 cells."""
    recs = []
    auto_dir = os.path.join(os.path.dirname(dry_dir.rstrip("/")), "dryrun_auto")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            fn = os.path.join(dry_dir, f"{a}_{s}_{mesh}.json")
            if os.path.exists(fn):
                with open(fn) as f:
                    recs.append(json.load(f))
                continue
            fb = os.path.join(auto_dir, f"{a}_{s}_{mesh}.json")
            if os.path.exists(fb):
                with open(fb) as f:
                    r = json.load(f)
                r["mesh"] = f"{mesh}(auto)"
                recs.append(r)
    return recs


def terms(r: dict) -> dict | None:
    """The three roofline terms; memory as a (floor, ceiling) band — floor
    analytic minimal HBM traffic, ceiling the XLA-CPU bytes-accessed.  The
    bottleneck/fraction use the floor (TPU-realistic) band edge."""
    rl = r.get("roofline")
    if not rl:
        return None
    from repro.launch.mesh import HW

    floor_b = analytic_memory_floor(r)
    mem_floor = (floor_b / HW.HBM_BW) if floor_b else rl["memory_s"]
    mem_floor = min(mem_floor, rl["memory_s"])  # never above the measured band
    out = dict(rl)
    out["memory_floor_s"] = mem_floor
    tri = {"compute": rl["compute_s"], "memory": mem_floor,
           "collective": rl["collective_s"]}
    out["bottleneck_floor"] = max(tri, key=tri.get)
    mx = max(tri.values())
    out["fraction"] = rl["compute_s"] / mx if mx > 0 else None
    return out


def fraction(r: dict) -> float | None:
    t = terms(r)
    return t["fraction"] if t else None


def fmt_row(r: dict) -> list:
    if r["status"] != "ok":
        return [r["arch"], r["shape"], r["mesh"], r["status"],
                r.get("skip_reason", r.get("error", ""))[:60]] + [""] * 8
    t = terms(r)
    return [
        r["arch"], r["shape"], r["mesh"], "ok", "",
        f"{t['compute_s']:.4g}", f"{t['memory_floor_s']:.4g}", f"{t['memory_s']:.4g}",
        f"{t['collective_s']:.4g}",
        t["bottleneck_floor"],
        f"{(t['model_flops_ratio'] or 0):.3f}",
        f"{t['fraction']:.3f}",
        f"{r.get('compile_s', '')}",
    ]


HEADER = ["arch", "shape", "mesh", "status", "note", "compute_s",
          "memory_floor_s", "memory_xlacpu_s", "collective_s", "bottleneck",
          "model_flops_ratio", "roofline_frac", "compile_s"]


def render_markdown(recs: list) -> str:
    lines = ["| " + " | ".join(HEADER) + " |",
             "|" + "---|" * len(HEADER)]
    for r in recs:
        lines.append("| " + " | ".join(str(x) for x in fmt_row(r)) + " |")
    return "\n".join(lines)


def main(quick: bool = False, dry_dir: str = "bench_out/dryrun", mesh: str = "single") -> dict:
    banner(f"roofline report ({mesh}-pod)")
    if mesh == "multi":
        print("  NOTE: multi-pod cells are lowered SCANNED (compile/fit proof); "
              "their flop census undercounts by ~num_layers — the §Roofline "
              "terms of record are the single-pod (unrolled) table")
    recs = load_records(dry_dir, mesh)
    if not recs:
        print(f"  no dry-run records in {dry_dir} — run repro.launch.dryrun first")
        return {"dryrun_records_present": False}
    rows = [fmt_row(r) for r in recs]
    write_csv(f"roofline_{mesh}.csv", rows, HEADER)
    md = render_markdown(recs)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"roofline_{mesh}.md"), "w") as f:
        f.write(md + "\n")
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] == "error"]
    print(f"  cells: {len(ok)} ok, {len(skip)} skip, {len(err)} error")
    for r in ok:
        t = terms(r)
        print(f"  {r['arch']:<22} {r['shape']:<12} {t['bottleneck_floor']:<10} "
              f"frac={t['fraction']:.3f} mfr={(t['model_flops_ratio'] or 0):.3f}")
    worst = sorted(ok, key=lambda r: fraction(r) or 1)[:3]
    if worst:
        print("  worst roofline fractions: "
              + ", ".join(f"{r['arch']}×{r['shape']}={fraction(r):.3f}" for r in worst))
    return {"dryrun_records_present": True, "all_cells_ok_or_skip": not err,
            "n_ok": len(ok), "n_skip": len(skip), "n_err": len(err)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="bench_out/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    a = ap.parse_args()
    main(dry_dir=a.dir, mesh=a.mesh)
