"""Hot-path microbenches: the four recorded paths of the PR-7 overhaul.

  * **key derivation** — content keys/sec for the bulk grouped-quantize path
    (``instance_content_keys``) vs the per-instance reference
    (``_content_key_single``), plus the memoized re-derive rate.  The bulk
    path stacks same-shape instances into one matrix, quantizes once, and
    hashes precomputed bytes — the acceptance bar is >= 10x per-instance.
  * **warm-cache replay** — ``solve_bulk`` inst/s on a fully warmed cache
    (every instance a hit, re-materialized through the batched
    ``simulate_bucket`` replay) vs the serial hit path (one instance per
    call, the per-instance Python the pre-overhaul hit loop paid per hit).
    Bar: batched >= 5x serial.
  * **session-to-direct ratio** — the chain serving mix through the
    coalescing front door vs raw ``solve_bulk`` (bench_session's helpers at
    the same scale).  Bar: >= 0.9 (the dispatch-slimming target; was 0.65).
  * **pivot-kernel roofline** — the tuned fused K-pivot kernel timed on the
    chain bucket's real tableau shape, placed on the roofline via
    ``benchmarks.roofline.kernel_roofline`` (informational on CPU
    interpret: the intensity/bottleneck columns are machine-independent).

CSV: bench_out/hotpath.csv.  The >=-bars are claims at full scale only
(CI smoke boxes make timing noise); smoke runs record the ratios
informationally.
"""

from __future__ import annotations

import gc
import time

import numpy as np

from repro.core.instance import random_instance

from .common import banner, write_csv

N_KEYS = 4096
N_WARM = 1024
N_SERIAL = 64  # serial-hit comparator instance count (one solve_bulk each)


def _key_instances(rng, n: int) -> list:
    """A mixed population (4 shape groups) so bulk grouping is exercised."""
    insts = []
    for i in range(n):
        topo = "chain" if i % 2 == 0 else "star"
        ret = 0.25 if i % 4 == 3 else 0.0
        insts.append(random_instance(
            rng, m=3 + (i % 2), n_loads=2, q=1, topology=topo,
            return_ratio=ret))
    return insts


def _bench_keys(rng, n: int) -> dict:
    from repro.core.keys import (_MEMO_ATTR, _content_key_single,
                                 instance_content_keys)

    insts = _key_instances(rng, n)

    def fresh():  # drop the memos so every bulk rep really derives
        for inst in insts:
            inst.__dict__.pop(_MEMO_ATTR, None)

    # median of 3 for both paths, gc.collect()ed like every timed loop in
    # this suite: the bulk pass allocates one large parts list per call, so
    # a pending collection from earlier benches lands right inside it and
    # the bulk/per-instance ratio becomes a function of bench ordering
    bulk_t, single_t = [], []
    for _ in range(3):
        fresh()
        gc.collect()
        t0 = time.perf_counter()
        bulk = instance_content_keys(insts)
        bulk_t.append(time.perf_counter() - t0)
        gc.collect()
        t0 = time.perf_counter()
        single = [_content_key_single(i) for i in insts]
        single_t.append(time.perf_counter() - t0)
        assert bulk == single, "bulk keys diverged from the per-instance oracle"
    gc.collect()
    t0 = time.perf_counter()
    memo = instance_content_keys(insts)  # all memo probes now
    memo_s = time.perf_counter() - t0
    assert memo == bulk
    return {
        "per_instance": n / sorted(single_t)[1],
        "bulk": n / sorted(bulk_t)[1],
        "memoized": n / memo_s,
    }


def _bench_warm_cache(problems: list, policy) -> dict:
    from repro.api import Session

    sess = Session(policy=policy)
    sess.solve_bulk(problems)  # cold fill: compile + populate the cache
    sess.solve_bulk(problems[:1])  # compile the single-instance replay rung
    gc.collect()  # same hygiene as bench_session: keep pending full
    # collections (earlier sub-benches' garbage) out of the timed loops
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        sess.solve_bulk(problems)  # every instance a hit -> batched replay
        times.append(time.perf_counter() - t0)
    warm = len(problems) / sorted(times)[len(times) // 2]
    serial_probs = problems[:N_SERIAL]
    t0 = time.perf_counter()
    for p in serial_probs:
        sess.solve_bulk([p])  # hits too, but one instance of Python each
    serial = len(serial_probs) / (time.perf_counter() - t0)
    return {"batched": warm, "serial": serial}


def _bench_pivot_roofline(quick: bool) -> dict | None:
    """Time the tuned K-pivot kernel on the chain bucket's tableau shape."""
    from jax.experimental import enable_x64

    from repro.engine.autotune import _probe_stack, cache_snapshot, pivot_schedule
    from repro.kernels.ops import scheduling_kernels_available, simplex_pivot

    from .roofline import kernel_roofline

    if not scheduling_kernels_available():
        return None
    # the chain-mix LP tableau shape (m=3, 2 loads, q=1) as solved by the
    # pallas driver; pivot_schedule memoizes, so a prior pallas solve in
    # this process would already have tuned it
    R, C = 8, 15
    tune = pivot_schedule(R, C)
    k = tune["k_pivots"]
    B = 16 if quick else 64
    T, basis, it, status = _probe_stack(R, C)
    reps = max(1, B // T.shape[0])
    T = np.tile(T, (reps, 1, 1))[:B]
    basis = np.tile(basis, (reps, 1))[:B]
    it = np.tile(it, reps)[:B]
    status = np.tile(status, reps)[:B]
    kw = dict(ncols_price=C - 1, bland_after=10_000, max_iter=10_000,
              k_pivots=k)
    with enable_x64():
        out = simplex_pivot(T, basis, it, status, **kw)  # compile
        out[0].block_until_ready()
        t0 = time.perf_counter()
        n_launch = 2 if quick else 8
        for _ in range(n_launch):
            out = simplex_pivot(T, basis, it, status, **kw)
        out[0].block_until_ready()
        dt = time.perf_counter() - t0
    pivots = B * k * n_launch
    # per pivot per lane: two one-hot contractions + the rank-1 update
    # (~6RC flops); minimal HBM traffic = read + write the tableau block
    rl = kernel_roofline(flops=pivots * 6 * R * C,
                         bytes_moved=pivots * 2 * R * C * 8, seconds=dt)
    rl["k_pivots"] = k
    rl["shape"] = f"{R}x{C}"
    rl["autotune_entries"] = len(cache_snapshot())
    return rl


def main(quick: bool = False) -> dict:
    from repro.api import Policy

    from .bench_session import _direct_throughput, _mix, _session_throughput

    banner("bench_hotpath (keys / warm cache / session ratio / pivot kernel)")
    policy = Policy(backend="batched")
    claims: dict = {}

    n_keys = 512 if quick else N_KEYS
    # dedicated rng per sub-bench: the populations stay identical no matter
    # which sub-benches run or how they're reordered (and the warm/session
    # mix reuses bench_session's seed-0 stream, so the ratio here is
    # measured on the same instances that bench drives)
    keys = _bench_keys(np.random.default_rng(11), n_keys)
    key_speedup = keys["bulk"] / keys["per_instance"]
    print(f"  keys/s: per-instance {keys['per_instance']:9.0f}   "
          f"bulk {keys['bulk']:9.0f} ({key_speedup:.1f}x)   "
          f"memoized {keys['memoized']:9.0f}")

    n_warm = 128 if quick else N_WARM
    problems = _mix(np.random.default_rng(0), n_warm, "chain")
    warm = _bench_warm_cache(problems, policy)
    warm_speedup = warm["batched"] / warm["serial"]
    print(f"  warm-cache hits: batched {warm['batched']:9.0f} inst/s   "
          f"serial {warm['serial']:9.0f} inst/s ({warm_speedup:.1f}x)")

    sess_ips, _ = _session_throughput(problems, policy)
    direct_ips = _direct_throughput(problems, policy)
    ratio = sess_ips / direct_ips
    print(f"  session-to-direct (chain): {sess_ips:9.0f} / {direct_ips:9.0f} "
          f"= {ratio:.2f}")

    rows = [
        ["keys_per_sec", "per_instance", keys["per_instance"]],
        ["keys_per_sec", "bulk", keys["bulk"]],
        ["keys_per_sec", "memoized", keys["memoized"]],
        ["warm_hit_inst_per_sec", "batched", warm["batched"]],
        ["warm_hit_inst_per_sec", "serial", warm["serial"]],
        ["session_to_direct_ratio", "chain", ratio],
    ]
    rl = _bench_pivot_roofline(quick)
    if rl:
        print(f"  pivot kernel ({rl['shape']}, K={rl['k_pivots']}): "
              f"intensity {rl['intensity_flop_per_byte']:.2f} FLOP/B, "
              f"{rl['achieved_gflops']:.2f} GFLOP/s achieved, "
              f"{rl['bottleneck']}-bound on the v5e roofline")
        rows.append(["pivot_intensity_flop_per_byte", rl["shape"],
                     rl["intensity_flop_per_byte"]])
        rows.append(["pivot_achieved_gflops", rl["shape"],
                     rl["achieved_gflops"]])
    write_csv("hotpath.csv", rows, ["metric", "label", "value"])

    if quick:
        claims["bulk_key_speedup"] = round(key_speedup, 1)
        claims["warm_hit_speedup"] = round(warm_speedup, 1)
        claims["session_to_direct_chain"] = round(ratio, 2)
    else:
        claims["bulk_keys_10x"] = key_speedup >= 10.0
        claims["warm_cache_5x_serial_hit"] = warm_speedup >= 5.0
        claims["session_to_direct_ge_090"] = ratio >= 0.9
    for k, v in claims.items():
        if isinstance(v, bool):
            print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
        else:
            print(f"  CLAIM {k} = {v} (informational at smoke scale)")
    return claims


if __name__ == "__main__":
    main()
