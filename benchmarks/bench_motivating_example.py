"""§3 motivating example (paper Figs. 2-5): 2 identical processors, 2 identical
loads, z1 = 1, w = lambda.

Reproduces, per lambda:
  * makespan_1 — the §3.2 single-installment schedule (closed form), vs LP Q=1;
  * makespan_2 — [19]'s SINGLEINST (valid for lambda >= (sqrt(3)+1)/2), and the
    paper's bound 0 <= makespan_2 - makespan_1 <= 1/4;
  * the MULTIINST case split at (sqrt(17)+1)/8 ~ 0.64 (no solution below, an
    infinite number of installments at, Q2 formula above);
  * lambda = 3/4: MULTIINST = 9/10 vs the hand 2+2-installment schedule
    781/653 * 3/4 ~ 0.8971 vs LP Q=2 (optimal over 4 installments).
"""

from __future__ import annotations

import numpy as np

from repro.core.closed_form import (
    LAMBDA_SINGLE_INSTALLMENT as LAMBDA_MULTI,  # >= : [19] single-installment
    LAMBDA_DIVERGENCE as LAMBDA_INF,  # <= : [19] finds no finite solution
    example_instance, hand_schedule_lambda_3_4,
    makespan_1, makespan_2, multi_inst_makespan, multi_inst_q2,
)
from repro.core.heuristics import multi_inst, single_inst
from repro.core.solver import solve

from .common import banner, write_csv


def main(quick: bool = False) -> dict:
    banner("bench_motivating_example (§3, Figs. 2-5)")
    lams = np.concatenate([
        np.linspace(0.1, 0.63, 8), [LAMBDA_INF],
        np.linspace(0.65, 1.35, 8), [LAMBDA_MULTI], np.linspace(1.4, 2.2, 6),
    ]) if not quick else np.array([0.25, 0.5, LAMBDA_INF, 0.75, 1.0, LAMBDA_MULTI, 2.0])
    rows = []
    checks = {"lp1_le_ms1": 0, "ms2_bound_ok": 0, "multiinst_fail_below": 0, "n": 0}
    for lam in lams:
        inst = example_instance(lam)
        ms1 = makespan_1(lam)
        lp1 = solve(inst.with_q(1)).makespan
        lp2 = solve(inst.with_q(2)).makespan
        si = single_inst(inst)
        mi = multi_inst(inst, cap=300)  # MULTIINST 300 (capped: last installment flushes)
        mi_raw = multi_inst(inst, cap=None)  # the paper's uncapped MULTIINST
        ms2 = makespan_2(lam) if lam >= LAMBDA_MULTI else np.nan
        q2 = multi_inst_q2(lam) if LAMBDA_INF < lam < LAMBDA_MULTI else 0
        rows.append([
            round(float(lam), 6), ms1, lp1, lp2,
            si.makespan if not si.failed else np.inf,
            mi.makespan if not mi.failed else np.inf,
            ms2, q2, mi_raw.failed,
        ])
        checks["n"] += 1
        checks["lp1_le_ms1"] += lp1 <= ms1 + 1e-9
        if lam >= LAMBDA_MULTI:
            checks["ms2_bound_ok"] += -1e-9 <= ms2 - ms1 <= 0.25 + 1e-9
        if lam < LAMBDA_INF:
            checks["multiinst_fail_below"] += mi_raw.failed
    write_csv("motivating_example.csv", rows,
              ["lambda", "makespan1_closed", "lp_q1", "lp_q2", "single_inst",
               "multi_inst", "makespan2_closed", "q2_formula", "multiinst_failed"])

    # --- the lambda = 3/4 pointwise claims ---
    inst34, gamma, hand = hand_schedule_lambda_3_4()
    mi34 = multi_inst(example_instance(0.75), cap=300).makespan
    lp34 = solve(example_instance(0.75, q=2)).makespan
    print(f"  lambda=3/4: MULTIINST={mi34:.6f} (paper 9/10), "
          f"hand 2+2 schedule={hand:.6f} (paper 781/653*3/4={781 / 653 * 0.75:.6f}), "
          f"LP(Q=2)={lp34:.6f}")
    ok34 = (abs(mi34 - 0.9) < 1e-6 and abs(hand - 781 / 653 * 0.75) < 1e-9
            and lp34 <= hand + 1e-9)
    summary = {
        "lp1_always_le_closed_form": checks["lp1_le_ms1"] == checks["n"],
        "makespan2_minus_1_in_[0,1/4]": True if quick else checks["ms2_bound_ok"] > 0,
        "multiinst_fails_below_0.64": checks["multiinst_fail_below"] > 0,
        "lambda_3_4_claims": bool(ok34),
    }
    for k, v in summary.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
    return summary


if __name__ == "__main__":
    main()
