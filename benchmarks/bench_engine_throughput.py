"""Engine throughput, three ways: serial NumPy loop vs the vmapped batched
engine vs the fused-Pallas-kernel backend.

Two measurements (paper §6 distributions):

  * solve throughput — `repro.core.solver.solve` in a Python loop (the
    pre-engine path: build LP, dense two-phase simplex, NumPy ASAP replay,
    feasibility validation) vs `repro.engine.solve_bulk` (bucketed batched
    simplex + vmapped replay) vs `solve_bulk(use_pallas=True)` (same bulk
    path with the pivot loop and replay in the fused kernels), over a
    1024-instance population of small instances so the serial loop finishes
    in benchmark time;
  * replay throughput — `repro.core.simulator.simulate` in a loop vs the
    vmapped ASAP simulator vs the fused replay kernel, on a campaign-scale
    sweep population (m=10, 5 loads in 5 installments — the §6 protocol
    sizes the sweeps actually replay).

Compile time is excluded from the batched/pallas numbers: one full warm-up
call compiles every (bucket, batch) shape first, as a production service
would reuse compiled shapes across ticks.  The acceptance bar is >= 10x
instances/sec on the batched solve path; the pallas columns are recorded for
the same populations (off-TPU the kernels run in interpret mode, so their
CPU numbers gauge the harness, not the silicon).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.instance import random_instance
from repro.core.simulator import simulate
from repro.core.solver import solve
from repro.engine import InstanceArena, makespans, simulate_bucket, solve_bulk

from .common import banner, write_csv

N_INSTANCES = 1024
M, N_LOADS, Q = 3, 2, 1  # small instances: the serial loop must finish
N_REPLAY = 512
M_R, N_LOADS_R, Q_R = 10, 5, 5  # §6 campaign scale for the replay path


def _population(n: int, rng, m=M, n_loads=N_LOADS, q=Q) -> list:
    return [random_instance(rng, m=m, n_loads=n_loads, q=q) for _ in range(n)]


def bench_solve(insts: list, serial_sample: int) -> tuple[dict, dict]:
    # serial: measure a sample and extrapolate (the whole point is that the
    # loop is too slow to run 1024 times inside a benchmark budget)
    t0 = time.perf_counter()
    for inst in insts[:serial_sample]:
        solve(inst, backend="simplex")
    serial_per = (time.perf_counter() - t0) / serial_sample
    out = {"serial": 1.0 / serial_per}

    n_fallback = {}
    for label, use_pallas in (("batched", False), ("pallas", True)):
        solve_bulk(insts, use_pallas=use_pallas)  # warm-up: compile shapes
        t0 = time.perf_counter()
        res = solve_bulk(insts, use_pallas=use_pallas)
        out[label] = len(insts) / (time.perf_counter() - t0)
        n_fallback[label] = sum(1 for r in res if r.backend != label)
    return out, n_fallback


def bench_replay(insts: list, gammas: list) -> dict:
    t0 = time.perf_counter()
    for inst, g in zip(insts, gammas):
        simulate(inst, g)
    out = {"serial": len(insts) / (time.perf_counter() - t0)}

    for label, use_pallas in (("batched", False), ("pallas", True)):
        arena = InstanceArena(insts, pad_shapes=True)
        for bucket in arena.buckets:  # warm-up per shape
            simulate_bucket(bucket, bucket.gamma_padded(
                [gammas[i] for i in bucket.indices]), use_pallas=use_pallas)
        t0 = time.perf_counter()
        makespans(insts, gammas, use_pallas=use_pallas)
        out[label] = len(insts) / (time.perf_counter() - t0)
    return out


def main(quick: bool = False) -> dict:
    banner("bench_engine_throughput (serial NumPy vs batched vs pallas)")
    rng = np.random.default_rng(0)
    n = 128 if quick else N_INSTANCES
    insts = _population(n, rng)

    solve_ips, n_fallback = bench_solve(insts, serial_sample=min(32, n))
    speedup = {k: solve_ips[k] / solve_ips["serial"] for k in ("batched", "pallas")}
    print(f"  solve:  serial {solve_ips['serial']:8.1f} inst/s   "
          f"batched {solve_ips['batched']:8.1f} inst/s ({speedup['batched']:.1f}x)   "
          f"pallas {solve_ips['pallas']:8.1f} inst/s ({speedup['pallas']:.1f}x)   "
          f"({n} instances, fallbacks {n_fallback})")

    # replay workload: SIMPLE-heuristic fractions over a campaign-scale
    # population (the heuristic-sweep shapes the batched simulator targets)
    replay_insts = _population(
        128 if quick else N_REPLAY, rng, m=M_R, n_loads=N_LOADS_R, q=Q_R)
    gammas = []
    for inst in replay_insts:
        speeds = 1.0 / inst.chain.w
        g = np.tile((speeds / speeds.sum())[:, None], (1, inst.total_installments))
        cells = list(inst.cells())
        for ln in range(inst.N):
            cols = [t for t, (l, _) in enumerate(cells) if l == ln]
            g[:, cols] /= len(cols)
        gammas.append(g)
    replay_ips = bench_replay(replay_insts, gammas)
    replay_speedup = {k: replay_ips[k] / replay_ips["serial"]
                      for k in ("batched", "pallas")}
    print(f"  replay: serial {replay_ips['serial']:8.1f} inst/s   "
          f"batched {replay_ips['batched']:8.1f} inst/s "
          f"({replay_speedup['batched']:.1f}x)   "
          f"pallas {replay_ips['pallas']:8.1f} inst/s "
          f"({replay_speedup['pallas']:.1f}x)")

    write_csv(
        "engine_throughput.csv",
        [["solve", solve_ips["serial"], solve_ips["batched"],
          solve_ips["pallas"], speedup["batched"], speedup["pallas"]],
         ["replay", replay_ips["serial"], replay_ips["batched"],
          replay_ips["pallas"], replay_speedup["batched"],
          replay_speedup["pallas"]]],
        ["path", "serial_inst_per_sec", "batched_inst_per_sec",
         "pallas_inst_per_sec", "batched_speedup", "pallas_speedup"],
    )

    claims = {
        "solve_10x": speedup["batched"] >= 10.0,
        "no_fallbacks": n_fallback["batched"] == 0,
        "no_pallas_fallbacks": n_fallback["pallas"] == 0,
        "replay_10x": replay_speedup["batched"] >= 10.0,
        "pallas_solve_runs": solve_ips["pallas"] > 0.0,
    }
    for k, v in claims.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
