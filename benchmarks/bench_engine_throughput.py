"""Engine throughput: batched JAX solve/simulate vs the serial NumPy loop.

Two measurements (paper §6 distributions):

  * solve throughput — `repro.core.solver.solve` in a Python loop (the
    pre-engine path: build LP, dense two-phase simplex, NumPy ASAP replay,
    feasibility validation) vs `repro.engine.solve_bulk` (bucketed batched
    simplex + vmapped replay), over a 1024-instance population of small
    instances so the serial loop finishes in benchmark time;
  * replay throughput — `repro.core.simulator.simulate` in a loop vs the
    vmapped ASAP simulator, on a campaign-scale sweep population (m=10,
    5 loads in 5 installments — the §6 protocol sizes the sweeps actually
    replay).

Compile time is excluded from the batched numbers: one full warm-up call
compiles every (bucket, batch) shape first, as a production service would
reuse compiled shapes across ticks.  The acceptance bar is >= 10x
instances/sec on the solve path.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.instance import random_instance
from repro.core.simulator import simulate
from repro.core.solver import solve
from repro.engine import InstanceArena, makespans, simulate_bucket, solve_bulk

from .common import banner, write_csv

N_INSTANCES = 1024
M, N_LOADS, Q = 3, 2, 1  # small instances: the serial loop must finish
N_REPLAY = 512
M_R, N_LOADS_R, Q_R = 10, 5, 5  # §6 campaign scale for the replay path


def _population(n: int, rng, m=M, n_loads=N_LOADS, q=Q) -> list:
    return [random_instance(rng, m=m, n_loads=n_loads, q=q) for _ in range(n)]


def bench_solve(insts: list, serial_sample: int) -> tuple:
    # serial: measure a sample and extrapolate (the whole point is that the
    # loop is too slow to run 1024 times inside a benchmark budget)
    t0 = time.perf_counter()
    for inst in insts[:serial_sample]:
        solve(inst, backend="simplex")
    serial_per = (time.perf_counter() - t0) / serial_sample
    serial_ips = 1.0 / serial_per

    solve_bulk(insts)  # warm-up: compile the (bucket, batch) shapes
    t0 = time.perf_counter()
    res = solve_bulk(insts)
    batched_dt = time.perf_counter() - t0
    batched_ips = len(insts) / batched_dt
    n_fallback = sum(1 for r in res if r.backend != "batched")
    return serial_ips, batched_ips, batched_dt, n_fallback


def bench_replay(insts: list, gammas: list) -> tuple:
    t0 = time.perf_counter()
    for inst, g in zip(insts, gammas):
        simulate(inst, g)
    serial_dt = time.perf_counter() - t0

    arena = InstanceArena(insts, pad_shapes=True)
    for bucket in arena.buckets:  # warm-up per shape
        simulate_bucket(bucket, bucket.gamma_padded(
            [gammas[i] for i in bucket.indices]))
    t0 = time.perf_counter()
    makespans(insts, gammas)
    batched_dt = time.perf_counter() - t0
    return len(insts) / serial_dt, len(insts) / batched_dt


def main(quick: bool = False) -> dict:
    banner("bench_engine_throughput (batched engine vs serial NumPy)")
    rng = np.random.default_rng(0)
    n = 128 if quick else N_INSTANCES
    insts = _population(n, rng)

    serial_ips, batched_ips, batched_dt, n_fallback = bench_solve(
        insts, serial_sample=min(32, n))
    speedup = batched_ips / serial_ips
    print(f"  solve:  serial {serial_ips:8.1f} inst/s   "
          f"batched {batched_ips:8.1f} inst/s   speedup {speedup:6.1f}x   "
          f"({n} instances in {batched_dt:.2f}s, {n_fallback} fallbacks)")

    # replay workload: SIMPLE-heuristic fractions over a campaign-scale
    # population (the heuristic-sweep shapes the batched simulator targets)
    replay_insts = _population(
        128 if quick else N_REPLAY, rng, m=M_R, n_loads=N_LOADS_R, q=Q_R)
    gammas = []
    for inst in replay_insts:
        speeds = 1.0 / inst.chain.w
        g = np.tile((speeds / speeds.sum())[:, None], (1, inst.total_installments))
        cells = list(inst.cells())
        for ln in range(inst.N):
            cols = [t for t, (l, _) in enumerate(cells) if l == ln]
            g[:, cols] /= len(cols)
        gammas.append(g)
    sim_serial_ips, sim_batched_ips = bench_replay(replay_insts, gammas)
    sim_speedup = sim_batched_ips / sim_serial_ips
    print(f"  replay: serial {sim_serial_ips:8.1f} inst/s   "
          f"batched {sim_batched_ips:8.1f} inst/s   speedup {sim_speedup:6.1f}x")

    write_csv("engine_throughput.csv",
              [["solve", serial_ips, batched_ips, speedup],
               ["replay", sim_serial_ips, sim_batched_ips, sim_speedup]],
              ["path", "serial_inst_per_sec", "batched_inst_per_sec", "speedup"])

    claims = {
        "solve_10x": speedup >= 10.0,
        "no_fallbacks": n_fallback == 0,
        "replay_10x": sim_speedup >= 10.0,
    }
    for k, v in claims.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
