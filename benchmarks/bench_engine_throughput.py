"""Engine throughput, three ways: serial NumPy loop vs the vmapped batched
engine vs the fused-Pallas-kernel backend.

Two measurements (paper §6 distributions):

  * solve throughput — `repro.core.solver.solve` in a Python loop (the
    pre-engine path: build LP, dense two-phase simplex, NumPy ASAP replay,
    feasibility validation) vs `repro.engine.solve_bulk` (bucketed batched
    simplex + vmapped replay) vs `solve_bulk(use_pallas=True)` (same bulk
    path with the pivot loop and replay in the fused kernels), over a
    1024-instance population of small instances so the serial loop finishes
    in benchmark time;
  * replay throughput — `repro.core.simulator.simulate` in a loop vs the
    vmapped ASAP simulator vs the fused replay kernel, on a campaign-scale
    sweep population (m=10, 5 loads in 5 installments — the §6 protocol
    sizes the sweeps actually replay).

The whole methodology — warm-up/compile exclusion, timing, the printed
report, the CSV schema, and the claims convention — lives once, in
benchmarks/common.py::three_way_bench, shared with bench_star; this module
only supplies the chain populations.  The acceptance bar is >= 10x
instances/sec on the batched solve path at full scale (smoke runs record
the ratio informationally — see common.throughput_claims); the pallas
columns are recorded for the same populations (off-TPU the kernels run in
interpret mode, so their CPU numbers gauge the harness, not the silicon).
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import random_instance

from .common import three_way_bench

N_INSTANCES = 1024
M, N_LOADS, Q = 3, 2, 1  # small instances: the serial loop must finish
N_REPLAY = 512
M_R, N_LOADS_R, Q_R = 10, 5, 5  # §6 campaign scale for the replay path


def _population(n: int, rng, m=M, n_loads=N_LOADS, q=Q) -> list:
    return [random_instance(rng, m=m, n_loads=n_loads, q=q) for _ in range(n)]


def main(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    return three_way_bench(
        "bench_engine_throughput (serial NumPy vs batched vs pallas)",
        solve_insts=_population(128 if quick else N_INSTANCES, rng),
        replay_insts=_population(128 if quick else N_REPLAY, rng,
                                 m=M_R, n_loads=N_LOADS_R, q=Q_R),
        csv_name="engine_throughput.csv",
        quick=quick,
    )


if __name__ == "__main__":
    main()
