"""Table 2 reproduction (§6): relative performance of every heuristic vs the
best result per instance, over randomized linear networks.

Protocol (scaled-down counts, same distributions): m=10 processors,
homogeneous (100 MFLOPS) or heterogeneous (10-100 MFLOPS) powers, link speeds
10-100 Mb/s with anti-correlated 0.1-1 ms latencies, 50 loads of 6-60 GFLOP
(x66 for the "large tasks" row), communication-to-computation ratio in
{0.01 .. 100} bytes/FLOP.

Heuristics: SIMPLE, SINGLELOAD 100, SINGLEINST, MULTIINST 100, MULTIINST 300,
HEURISTIC B, LP 1/2/3/6 (our linear program).  Statistic: makespan divided by
the per-instance best, as in the paper's Table 2.
"""

from __future__ import annotations

import numpy as np

from repro.core.heuristics import heuristic_b, multi_inst, simple, single_inst, single_load
from repro.core.instance import Chain, Instance, Loads, random_instance
from repro.core.solver import solve

from .common import banner, rel_stats, write_csv

CCRS_FULL = [0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0]
CCRS_QUICK = [0.01, 0.1, 1.0, 10.0, 100.0]


def _scaled(inst: Instance, scale: float) -> Instance:
    if scale == 1.0:
        return inst
    return Instance(
        inst.chain,
        Loads(v_comm=inst.loads.v_comm * scale, v_comp=inst.loads.v_comp * scale),
        q=1,
    )


def _methods(quick: bool):
    ms = {
        "SIMPLE": lambda i: simple(i).makespan,
        "SINGLELOAD_100": lambda i: single_load(i).makespan,
        "SINGLEINST": lambda i: single_inst(i).makespan,
        "MULTIINST_100": lambda i: multi_inst(i, cap=100).makespan,
        "HEURISTIC_B": lambda i: heuristic_b(i).makespan,
        "LP_1": lambda i: solve(i.with_q(1)).makespan,
        "LP_2": lambda i: solve(i.with_q(2)).makespan,
    }
    if not quick:
        ms["MULTIINST_300"] = lambda i: multi_inst(i, cap=300).makespan
        ms["LP_3"] = lambda i: solve(i.with_q(3)).makespan
        ms["LP_6"] = lambda i: solve(i.with_q(6)).makespan
    return ms


def main(quick: bool = False) -> dict:
    banner("bench_table2 (§6, Table 2)")
    rng = np.random.default_rng(0)
    ccrs = CCRS_QUICK if quick else CCRS_FULL
    n_inst = 2 if quick else 4
    n_loads = 10 if quick else 50
    methods = _methods(quick)
    vals = {k: [] for k in methods}
    rows = []
    n_total = 0
    for het in (False, True):
        for size_scale in (1.0, 66.0):
            for ccr in ccrs:
                for k in range(n_inst):
                    inst = _scaled(
                        random_instance(rng, m=10, n_loads=n_loads, heterogeneous=het,
                                        comm_to_comp=ccr, with_latency=True),
                        size_scale,
                    )
                    got = {name: fn(inst) for name, fn in methods.items()}
                    best = min(got.values())
                    n_total += 1
                    for name, v in got.items():
                        rel = v / best if np.isfinite(v) else np.inf
                        vals[name].append(rel)
                        rows.append([het, size_scale, ccr, k, name, v, rel])
    write_csv("table2_raw.csv", rows,
              ["heterogeneous", "size_scale", "ccr", "rep", "heuristic",
               "makespan", "relative"])

    summary_rows = []
    print(f"  {n_total} instances; relative-to-best statistics:")
    print(f"  {'heuristic':<16} {'avg':>12} {'std':>12} {'max':>12} {'fail%':>7}")
    stats = {}
    for name in methods:
        arr = np.array(vals[name])
        fin = arr[np.isfinite(arr)]
        fail = 100.0 * (1 - len(fin) / len(arr))
        avg, std, mx = rel_stats(fin) if len(fin) else (np.inf,) * 3
        stats[name] = (avg, std, mx, fail)
        summary_rows.append([name, avg, std, mx, fail])
        print(f"  {name:<16} {avg:>12.5f} {std:>12.2e} {mx:>12.5f} {fail:>6.1f}%")
    write_csv("table2_summary.csv", summary_rows,
              ["heuristic", "avg_relative", "std", "max_relative", "fail_pct"])

    lp_names = [n for n in methods if n.startswith("LP_")]
    best_lp = f"LP_{max(int(n.split('_')[1]) for n in lp_names)}"
    # quick mode uses 10-load instances where the pipeline-fill fraction (and
    # hence the multi-installment gain LP_1 forgoes) is ~5x larger than in the
    # paper's 50-load protocol — thresholds widen accordingly
    lp_tol, si_tol = (1.02, 1.20) if quick else (1.005, 1.10)
    claims = {
        # paper: LP n always <= 0.5% from the best (50-load protocol)
        "lp_near_best": all(stats[n][0] < lp_tol for n in lp_names),
        # paper: highest-Q LP is (essentially) always the best
        "best_lp_avg_1.0": stats[best_lp][0] < 1.0005,
        # paper: SIMPLE catastrophic on some instances
        "simple_max_over_2x": stats["SIMPLE"][2] > 2.0,
        # paper: SINGLEINST within ~6% of optimal on average (where it exists)
        "singleinst_close": stats["SINGLEINST"][0] < si_tol,
    }
    for k, v in claims.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
