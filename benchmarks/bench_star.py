"""Star-platform throughput, three ways: serial NumPy loop vs the vmapped
batched engine vs the fused-Pallas-kernel backend — the first non-chain
workload class through the whole stack.

Two measurements, mirroring bench_engine_throughput on the star topology:

  * solve throughput — one-port-master LPs over a population of small star
    instances, with the result-return phase active on half of them so both
    bucket row patterns (with/without the return variable block) are
    exercised in the same bulk call;
  * replay throughput — the ASAP star recurrence (serialized master port +
    return chain) on a campaign-scale sweep population, every instance
    with returns.

The whole methodology — timing, report, CSV schema, claims — lives once,
in benchmarks/common.py::three_way_bench, shared with the chain bench;
this module only supplies the star populations.  The acceptance bar is the
same shape: at full scale the batched solve path must clear >= 10x the
serial loop with zero fallbacks (a fallback would mean the star LP or its
replay is mis-certified), and the chain numbers recorded by
bench_engine_throughput must be unaffected — the star families are new
rows in new buckets, never new work on chain paths.
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import random_instance

from .common import three_way_bench

N_INSTANCES = 1024
M, N_LOADS, Q = 3, 2, 1  # small instances: the serial loop must finish
N_REPLAY = 512
M_R, N_LOADS_R, Q_R = 10, 5, 5  # §6 campaign scale for the replay path
RETURN_RATIO = 0.5


def _population(n: int, rng) -> list:
    # half the population with the result-return phase: two bucket families
    return [
        random_instance(rng, m=M, n_loads=N_LOADS, q=Q, topology="star",
                        return_ratio=RETURN_RATIO if k % 2 else 0.0)
        for k in range(n)
    ]


def main(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    replay_insts = [
        random_instance(rng, m=M_R, n_loads=N_LOADS_R, q=Q_R, topology="star",
                        return_ratio=RETURN_RATIO)
        for _ in range(128 if quick else N_REPLAY)
    ]
    return three_way_bench(
        "bench_star (star topology: serial NumPy vs batched vs pallas)",
        solve_insts=_population(128 if quick else N_INSTANCES, rng),
        replay_insts=replay_insts,
        csv_name="star_throughput.csv",
        quick=quick,
        solve_note="star (half-with-returns) ",
    )


if __name__ == "__main__":
    main()
