"""Shared benchmark plumbing: CSV writing, timing, tiny stats."""

from __future__ import annotations

import csv
import os
import time

import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", "bench_out")


def write_csv(name: str, rows: list, header: list) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    print(f"  wrote {path} ({len(rows)} rows)")
    return path


def timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, time.perf_counter() - t0


def rel_stats(rel: np.ndarray) -> tuple:
    return float(np.mean(rel)), float(np.std(rel)), float(np.max(rel))


def banner(title: str):
    print(f"\n=== {title} ===")
