"""Shared benchmark plumbing: CSV writing, timing, tiny stats."""

from __future__ import annotations

import csv
import os
import time

import numpy as np

OUT_DIR = os.environ.get("BENCH_OUT", "bench_out")


def write_csv(name: str, rows: list, header: list) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    print(f"  wrote {path} ({len(rows)} rows)")
    return path


def timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, time.perf_counter() - t0


def rel_stats(rel: np.ndarray) -> tuple:
    return float(np.mean(rel)), float(np.std(rel)), float(np.max(rel))


def banner(title: str):
    print(f"\n=== {title} ===")


# --------------------------------------------------------------------------
# shared three-way (serial / batched / pallas) throughput machinery, used by
# bench_engine_throughput (chain) and bench_star (star) so the timing
# methodology and the claims convention exist exactly once
# --------------------------------------------------------------------------


def three_way_solve(insts: list, serial_sample: int) -> tuple[dict, dict]:
    """inst/s for the serial solve loop vs solve_bulk vs solve_bulk(pallas).

    The serial loop measures a sample and extrapolates (the whole point is
    that it is too slow to run the full population inside a benchmark
    budget); the engine paths get one full warm-up call so every (bucket,
    batch) shape is compiled before timing, as a serving process would
    reuse compiled shapes across ticks.  Also returns per-path fallback
    counts (elements whose report came from a different backend).
    """
    from repro.core.solver import solve
    from repro.engine import solve_bulk

    t0 = time.perf_counter()
    for inst in insts[:serial_sample]:
        solve(inst, backend="simplex")
    serial_per = (time.perf_counter() - t0) / serial_sample
    out = {"serial": 1.0 / serial_per}

    n_fallback = {}
    for label, use_pallas in (("batched", False), ("pallas", True)):
        solve_bulk(insts, use_pallas=use_pallas)  # warm-up: compile shapes
        t0 = time.perf_counter()
        res = solve_bulk(insts, use_pallas=use_pallas)
        out[label] = len(insts) / (time.perf_counter() - t0)
        n_fallback[label] = sum(1 for r in res if r.backend != label)
    return out, n_fallback


def three_way_replay(insts: list, gammas: list) -> dict:
    """inst/s for the serial ASAP replay vs the vmapped vs the fused kernel."""
    from repro.core.simulator import simulate
    from repro.engine import InstanceArena, makespans, simulate_bucket

    t0 = time.perf_counter()
    for inst, g in zip(insts, gammas):
        simulate(inst, g)
    out = {"serial": len(insts) / (time.perf_counter() - t0)}

    for label, use_pallas in (("batched", False), ("pallas", True)):
        arena = InstanceArena(insts, pad_shapes=True)
        for bucket in arena.buckets:  # warm-up per shape
            simulate_bucket(bucket, bucket.gamma_padded(
                [gammas[i] for i in bucket.indices]), use_pallas=use_pallas)
        t0 = time.perf_counter()
        makespans(insts, gammas, use_pallas=use_pallas)
        out[label] = len(insts) / (time.perf_counter() - t0)
    return out


def speed_proportional_gammas(insts: list) -> list:
    """Per-instance [m, T] fractions proportional to processor speeds, each
    load split evenly over its installments (the SIMPLE-heuristic shape the
    replay sweeps target)."""
    gammas = []
    for inst in insts:
        speeds = 1.0 / inst.platform.w
        g = np.tile((speeds / speeds.sum())[:, None], (1, inst.total_installments))
        cells = list(inst.cells())
        for ln in range(inst.N):
            cols = [t for t, (l, _) in enumerate(cells) if l == ln]
            g[:, cols] /= len(cols)
        gammas.append(g)
    return gammas


def three_way_bench(title: str, solve_insts: list, replay_insts: list,
                    csv_name: str, quick: bool, solve_note: str = "") -> dict:
    """The whole three-way throughput bench, once: solve + replay timing,
    the printed report, the CSV, and the claims.  A bench module supplies
    only its populations and labels."""
    banner(title)
    n = len(solve_insts)
    solve_ips, n_fallback = three_way_solve(solve_insts, serial_sample=min(32, n))
    speedup = {k: solve_ips[k] / solve_ips["serial"] for k in ("batched", "pallas")}
    print(f"  solve:  serial {solve_ips['serial']:8.1f} inst/s   "
          f"batched {solve_ips['batched']:8.1f} inst/s ({speedup['batched']:.1f}x)   "
          f"pallas {solve_ips['pallas']:8.1f} inst/s ({speedup['pallas']:.1f}x)   "
          f"({n} {solve_note}instances, fallbacks {n_fallback})")

    gammas = speed_proportional_gammas(replay_insts)
    replay_ips = three_way_replay(replay_insts, gammas)
    replay_speedup = {k: replay_ips[k] / replay_ips["serial"]
                      for k in ("batched", "pallas")}
    print(f"  replay: serial {replay_ips['serial']:8.1f} inst/s   "
          f"batched {replay_ips['batched']:8.1f} inst/s "
          f"({replay_speedup['batched']:.1f}x)   "
          f"pallas {replay_ips['pallas']:8.1f} inst/s "
          f"({replay_speedup['pallas']:.1f}x)")

    write_csv(
        csv_name,
        [["solve", solve_ips["serial"], solve_ips["batched"],
          solve_ips["pallas"], speedup["batched"], speedup["pallas"]],
         ["replay", replay_ips["serial"], replay_ips["batched"],
          replay_ips["pallas"], replay_speedup["batched"],
          replay_speedup["pallas"]]],
        ["path", "serial_inst_per_sec", "batched_inst_per_sec",
         "pallas_inst_per_sec", "batched_speedup", "pallas_speedup"],
    )
    return throughput_claims(quick, speedup, replay_speedup, solve_ips,
                             n_fallback)


def throughput_claims(quick: bool, speedup: dict, replay_speedup: dict,
                      solve_ips: dict, n_fallback: dict) -> dict:
    """The shared claims convention: correctness claims always gate; the 10x
    speedup bars are full-scale statements (1024/512-instance populations) —
    a smoke run measures small batches on a possibly-contended CI box,
    where a ratio of two timings taken at different moments is noise, so
    quick mode records the ratios informationally instead of gating."""
    claims = {
        "no_fallbacks": n_fallback["batched"] == 0,
        "no_pallas_fallbacks": n_fallback["pallas"] == 0,
        "pallas_solve_runs": solve_ips["pallas"] > 0.0,
    }
    if quick:
        claims["solve_speedup"] = round(speedup["batched"], 2)
        claims["replay_speedup"] = round(replay_speedup["batched"], 2)
    else:
        claims["solve_10x"] = speedup["batched"] >= 10.0
        claims["replay_10x"] = replay_speedup["batched"] >= 10.0
    for k, v in claims.items():
        if isinstance(v, bool):
            print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
        else:
            print(f"  CLAIM {k} = {v} (informational at smoke scale)")
    return claims
