"""Serving-layer benchmarks: warm-restart ratio through the persistent plan
store, and sharded-fan-out parity/scaling (the PR-10 planning-service claims).

Two rungs:

  * **warm restart** — a star-with-returns population is solved through a
    fresh ``TieredSolutionCache`` over an empty sqlite store (the "first
    process": every instance a store miss, LP solved, plan persisted), then
    again through a *new* ``TieredSolutionCache`` over the same file (the
    "second process": every instance a store hit, plan replayed).  The
    restart is modelled as a fresh tiered cache rather than a literal
    ``subprocess`` because a real second process would spend its wall-clock
    importing jax and re-compiling shapes — constants that swamp the store's
    contribution and that ``bench_out`` already prices elsewhere;
    cross-process correctness is proven separately by the two-process hammer
    in tests/test_plan_store.py.  Solve and replay shapes are compiled
    before any timer starts.  Acceptance bar: warm >= 5x cold at full
    scale, and every warm lookup must be a store hit.  Gamma parity between
    the store-hit artifacts and the cold solve is asserted (<= 1e-9) every
    run — a fast wrong answer is not a speedup.
  * **shard fan-out** — ``solve_bulk_sharded`` vs plain ``solve_bulk`` on
    the same population.  With one local device (the usual CI box) the
    sharded path degenerates to thread fan-out over logical shards, where
    "scaling" is contention noise — so this rung gates *parity* (gamma
    <= 1e-9 against single-device) and records the throughput ratio
    informationally, per the 1-device degenerate case contract.  With >= 2
    real devices the same rows capture the near-linear scaling number.

CSV: bench_out/serve.csv.  The warm-restart rows feed the regression gate
(``repro_bench_serve_*``); the shard throughput rows stay CSV-only because
a 1-device "scaling" ratio is not a stable number to gate on.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from .common import banner, write_csv

N_FULL = 64  # store population at full scale
N_QUICK = 16
M, N_LOADS, Q = 6, 3, 2  # big enough that solving dwarfs replay (>=5x bar)
N_SHARDS = 2


def _population(rng, n: int) -> list:
    from repro.core.instance import random_instance

    return [
        random_instance(rng, m=M, n_loads=N_LOADS, q=Q, topology="star",
                        return_ratio=0.25)
        for _ in range(n)
    ]


def _max_gamma_diff(a: list, b: list) -> float:
    return max(
        float(np.max(np.abs(np.asarray(ra.schedule.gamma)
                            - np.asarray(rb.schedule.gamma))))
        for ra, rb in zip(a, b)
    )


def _bench_warm_restart(insts: list) -> dict:
    from repro.engine.service import solve_bulk
    from repro.serve import TieredSolutionCache

    solve_bulk(insts, cache=None)  # compile the solve shapes
    path = os.path.join(tempfile.mkdtemp(prefix="bench_serve_"), "plans.sqlite")

    cold_cache = TieredSolutionCache(path)
    t0 = time.perf_counter()
    cold = solve_bulk(insts, cache=cold_cache)
    cold_t = time.perf_counter() - t0

    # compile the store-hit replay shapes before timing the warm restart
    solve_bulk(insts, cache=TieredSolutionCache(path))

    warm_cache = TieredSolutionCache(path)  # the "second process"
    t0 = time.perf_counter()
    warm = solve_bulk(insts, cache=warm_cache)
    warm_t = time.perf_counter() - t0

    diff = _max_gamma_diff(cold, warm)
    assert diff <= 1e-9, f"store-hit gamma diverged from cold solve: {diff}"
    return {
        "cold": len(insts) / cold_t,
        "warm": len(insts) / warm_t,
        "ratio": cold_t / warm_t,
        "store_hits": warm_cache.store_hits,
        "gamma_diff": diff,
    }


def _bench_shard(insts: list) -> dict:
    from repro.engine.service import solve_bulk
    from repro.serve import local_devices, solve_bulk_sharded

    devices = local_devices()
    kw = ({"devices": devices} if len(devices) >= N_SHARDS
          else {"n_shards": N_SHARDS})

    solve_bulk(insts)  # warm-up both paths
    solve_bulk_sharded(insts, **kw)

    t0 = time.perf_counter()
    single = solve_bulk(insts)
    single_t = time.perf_counter() - t0
    t0 = time.perf_counter()
    sharded = solve_bulk_sharded(insts, **kw)
    sharded_t = time.perf_counter() - t0

    return {
        "single": len(insts) / single_t,
        "sharded": len(insts) / sharded_t,
        "scaling": single_t / sharded_t,
        "n_devices": len(devices),
        "gamma_diff": _max_gamma_diff(single, sharded),
    }


def main(quick: bool = False) -> dict:
    banner("bench_serve (persistent-store warm restart / sharded fan-out)")
    claims: dict = {}
    n = N_QUICK if quick else N_FULL
    insts = _population(np.random.default_rng(23), n)

    wr = _bench_warm_restart(insts)
    print(f"  warm restart ({n} instances, m={M}): "
          f"cold {wr['cold']:8.1f} inst/s   warm {wr['warm']:8.1f} inst/s "
          f"({wr['ratio']:.1f}x, {wr['store_hits']}/{n} store hits)")

    sh = _bench_shard(insts)
    mode = (f"{sh['n_devices']} devices" if sh["n_devices"] >= N_SHARDS
            else f"1 device, {N_SHARDS} logical shards")
    print(f"  shard fan-out ({mode}): "
          f"single {sh['single']:8.1f} inst/s   sharded {sh['sharded']:8.1f} "
          f"inst/s ({sh['scaling']:.2f}x, gamma diff {sh['gamma_diff']:.1e})")

    write_csv(
        "serve.csv",
        [
            ["serve_inst_per_sec", "cold", wr["cold"]],
            ["serve_inst_per_sec", "warm", wr["warm"]],
            ["serve_warm_restart_ratio", "store", wr["ratio"]],
            ["serve_shard_inst_per_sec", "single", sh["single"]],
            ["serve_shard_inst_per_sec", "sharded", sh["sharded"]],
            ["serve_shard_scaling", f"devices={sh['n_devices']}", sh["scaling"]],
            ["serve_shard_gamma_diff", "max", sh["gamma_diff"]],
        ],
        ["metric", "label", "value"],
    )

    claims["store_hits_complete"] = wr["store_hits"] == n
    claims["shard_parity_1e9"] = sh["gamma_diff"] <= 1e-9
    if quick:
        claims["warm_restart_ratio"] = round(wr["ratio"], 1)
        claims["shard_scaling"] = round(sh["scaling"], 2)
    else:
        claims["warm_restart_5x"] = wr["ratio"] >= 5.0
        if sh["n_devices"] >= N_SHARDS:
            claims["shard_scaling_1p5x"] = sh["scaling"] >= 1.5
        else:
            claims["shard_scaling"] = round(sh["scaling"], 2)
    for k, v in claims.items():
        if isinstance(v, bool):
            print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
        else:
            print(f"  CLAIM {k} = {v} (informational)")
    return claims


if __name__ == "__main__":
    main()
