"""Benchmark orchestrator: one module per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--full]`` (quick mode is the default so
CI stays fast; --full reproduces the paper-scale statistics).

Every run ends by writing ``bench_out/summary.json`` — a schema-versioned,
git-SHA-stamped merge of every bench CSV in ``bench_out/`` plus the claims
of this run and the key throughput metrics rendered through the metrics
registry (DESIGN.md §8).  ``--summary-only`` rebuilds the summary from the
CSVs already on disk without running any bench (the committed CSVs hold the
full-scale numbers; a laptop smoke run should not overwrite them just to
refresh the summary).  ``scripts/check_regression.py`` consumes the summary.
"""

from __future__ import annotations

import argparse
import csv
import glob
import json
import os
import platform
import subprocess
import sys
import time

from .common import OUT_DIR

SUMMARY_SCHEMA_VERSION = 1

# CSV stem -> (bench label, throughput columns) for the metrics rendering
_THROUGHPUT_CSVS = {"engine_throughput": "chain", "star_throughput": "star"}


def _git_sha() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=10,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def _json_safe(v):
    """Claims dicts mix python/numpy scalars; normalize for json.dump."""
    import numpy as np

    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(v)
    return v


def collect_benches(out_dir: str = OUT_DIR) -> dict:
    """Every bench CSV in ``out_dir`` as {stem: {header, rows}}."""
    benches = {}
    for path in sorted(glob.glob(os.path.join(out_dir, "*.csv"))):
        stem = os.path.splitext(os.path.basename(path))[0]
        with open(path, newline="") as f:
            rows = list(csv.reader(f))
        if rows:
            benches[stem] = {"header": rows[0], "rows": rows[1:]}
    return benches


def bench_metrics(benches: dict) -> dict:
    """Render the key throughput numbers through a metrics registry.

    The summary's ``metrics`` section IS a registry snapshot — the same
    ``name{label=value}`` key schema the live process exports, so the
    regression gate and a Prometheus scrape read identical names.
    """
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    for stem, bench in _THROUGHPUT_CSVS.items():
        b = benches.get(stem)
        if not b:
            continue
        for row in b["rows"]:
            rec = dict(zip(b["header"], row))
            for path in ("serial", "batched", "pallas"):
                reg.set_gauge("repro_bench_inst_per_sec",
                              float(rec[f"{path}_inst_per_sec"]),
                              bench=bench, op=rec["path"], path=path)
    b = benches.get("session_throughput")
    if b:
        for row in b["rows"]:
            rec = dict(zip(b["header"], row))
            reg.set_gauge("repro_bench_session_inst_per_sec",
                          float(rec["session_inst_per_sec"]), mix=rec["mix"])
            reg.set_gauge("repro_bench_session_to_direct_ratio",
                          float(rec["session_to_direct_ratio"]), mix=rec["mix"])
    b = benches.get("hotpath")
    if b:
        for row in b["rows"]:
            rec = dict(zip(b["header"], row))
            if rec["metric"] == "keys_per_sec":
                reg.set_gauge("repro_bench_keys_per_sec",
                              float(rec["value"]), path=rec["label"])
            elif rec["metric"] == "warm_hit_inst_per_sec":
                reg.set_gauge("repro_bench_warm_hit_inst_per_sec",
                              float(rec["value"]), path=rec["label"])
            elif rec["metric"] == "session_to_direct_ratio":
                reg.set_gauge("repro_bench_session_to_direct_ratio",
                              float(rec["value"]), mix=f"hotpath_{rec['label']}")
    b = benches.get("replan")
    if b:
        for row in b["rows"]:
            rec = dict(zip(b["header"], row))
            if rec["metric"] == "replan_solve_per_sec":
                reg.set_gauge("repro_bench_replan_solves_per_sec",
                              float(rec["value"]), path=rec["label"])
            elif rec["metric"] == "replan_warm_speedup":
                reg.set_gauge("repro_bench_replan_warm_speedup",
                              float(rec["value"]), layer=rec["label"])
            elif rec["metric"] == "replan_event_per_sec":
                reg.set_gauge("repro_bench_replan_events_per_sec",
                              float(rec["value"]), path=rec["label"])
    b = benches.get("serve")
    if b:
        # only the warm-restart rows are gated; the shard throughput rows
        # stay CSV-only (a 1-device "scaling" ratio is contention noise)
        for row in b["rows"]:
            rec = dict(zip(b["header"], row))
            if rec["metric"] == "serve_inst_per_sec":
                reg.set_gauge("repro_bench_serve_inst_per_sec",
                              float(rec["value"]), path=rec["label"])
            elif rec["metric"] == "serve_warm_restart_ratio":
                reg.set_gauge("repro_bench_serve_warm_restart_ratio",
                              float(rec["value"]), layer=rec["label"])
    return reg.snapshot()


def build_summary(claims: dict, failures: list, elapsed_s: float,
                  quick: bool | None) -> dict:
    benches = collect_benches()
    return {
        "schema_version": SUMMARY_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "created_unix": time.time(),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "quick": quick,  # None: --summary-only (no bench ran in this process)
        "elapsed_s": elapsed_s,
        "claims": {k: _json_safe(v) for k, v in claims.items()},
        "failures": [{"bench": n, "error": e} for n, e in failures],
        "benches": benches,
        "metrics": bench_metrics(benches),
    }


def validate_summary(d: dict) -> list:
    """Schema check for summary.json; returns a list of problems (empty = ok)."""
    errs = []
    if d.get("schema_version") != SUMMARY_SCHEMA_VERSION:
        errs.append(f"schema_version: want {SUMMARY_SCHEMA_VERSION}, "
                    f"got {d.get('schema_version')!r}")
    for key, typ in (("host", dict), ("claims", dict), ("failures", list),
                     ("benches", dict), ("metrics", dict)):
        if not isinstance(d.get(key), typ):
            errs.append(f"{key}: want {typ.__name__}, got {type(d.get(key)).__name__}")
    if not isinstance(d.get("created_unix"), (int, float)):
        errs.append("created_unix: want a unix timestamp")
    if d.get("git_sha") is not None and (
        not isinstance(d["git_sha"], str) or len(d["git_sha"]) < 7
    ):
        errs.append(f"git_sha: want null or a >=7-char sha, got {d['git_sha']!r}")
    for stem, b in (d.get("benches") or {}).items():
        if not isinstance(b, dict) or "header" not in b or "rows" not in b:
            errs.append(f"benches[{stem}]: want {{header, rows}}")
            continue
        w = len(b["header"])
        if any(len(r) != w for r in b["rows"]):
            errs.append(f"benches[{stem}]: ragged rows (header width {w})")
    for k, v in (d.get("metrics") or {}).items():
        if not isinstance(k, str) or not isinstance(v, (int, float)):
            errs.append(f"metrics[{k!r}]: want str -> number")
    return errs


def write_summary(claims: dict, failures: list, elapsed_s: float,
                  quick: bool | None) -> str:
    summary = build_summary(claims, failures, elapsed_s, quick)
    errs = validate_summary(summary)
    if errs:  # never ship a summary the CI validator would reject
        raise AssertionError(f"summary.json failed its own schema: {errs}")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "summary.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(summary['benches'])} benches, "
          f"{len(summary['metrics'])} metrics)")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale instance counts")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: quick mode over the engine-facing benches "
                         "(three-way engine throughput + kernels) unless "
                         "--only narrows it further")
    ap.add_argument("--summary-only", action="store_true",
                    help="rebuild bench_out/summary.json from the CSVs "
                         "already on disk; runs no bench")
    args = ap.parse_args(argv)
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    if args.summary_only:
        write_summary({}, [], 0.0, quick=None)
        return 0
    quick = not args.full
    if args.smoke and not args.only:
        args.only = "engine_throughput,star,kernels,session,hotpath,replan,serve"

    from . import (bench_campaign, bench_engine_throughput, bench_hotpath,
                   bench_kernels, bench_latency_qstar, bench_lp_scaling,
                   bench_motivating_example, bench_replan, bench_serve,
                   bench_session, bench_star, bench_table2, bench_theorem1,
                   roofline)

    benches = {
        "motivating_example": bench_motivating_example.main,
        "table2": bench_table2.main,
        "theorem1": bench_theorem1.main,
        "latency_qstar": bench_latency_qstar.main,
        "lp_scaling": bench_lp_scaling.main,
        "kernels": bench_kernels.main,
        "engine_throughput": bench_engine_throughput.main,
        "star": bench_star.main,
        "session": bench_session.main,
        "hotpath": bench_hotpath.main,
        "replan": bench_replan.main,
        "serve": bench_serve.main,
        # not in the --smoke only-list: CI gives the campaign its own
        # dedicated step (python -m repro.eval --smoke + check_campaign.py)
        "campaign": bench_campaign.main,
        "roofline_single": lambda quick: roofline.main(quick, mesh="single"),
        "roofline_multi": lambda quick: roofline.main(quick, mesh="multi"),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    all_claims = {}
    failures = []
    t0 = time.time()
    for name, fn in benches.items():
        try:
            claims = fn(quick) or {}
        except Exception as e:  # keep going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, f"{type(e).__name__}: {e}"))
            continue
        for k, v in claims.items():
            all_claims[f"{name}.{k}"] = v

    elapsed = time.time() - t0
    print(f"\n=== summary ({elapsed:.1f}s) ===")
    bad = [k for k, v in all_claims.items() if v is False]
    for k, v in sorted(all_claims.items()):
        import numpy as _np
        if not isinstance(v, (bool, _np.bool_)):
            print(f"  --  {k} = {v}")  # informational (counts etc.)
            continue
        print(f"  {'OK ' if v else 'BAD'} {k} = {v}")
    for name, err in failures:
        print(f"  ERR {name}: {err}")
    print(f"{len(all_claims) - len(bad)}/{len(all_claims)} claims OK, "
          f"{len(failures)} bench errors")
    write_summary(all_claims, failures, elapsed, quick)
    return 1 if (bad or failures) else 0


if __name__ == "__main__":
    sys.exit(main())
