"""Benchmark orchestrator: one module per paper table/figure + the roofline
report.  ``python -m benchmarks.run [--full]`` (quick mode is the default so
CI stays fast; --full reproduces the paper-scale statistics).
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale instance counts")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: quick mode over the engine-facing benches "
                         "(three-way engine throughput + kernels) unless "
                         "--only narrows it further")
    args = ap.parse_args(argv)
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    quick = not args.full
    if args.smoke and not args.only:
        args.only = "engine_throughput,star,kernels,session"

    from . import (bench_engine_throughput, bench_kernels, bench_latency_qstar,
                   bench_lp_scaling, bench_motivating_example, bench_session,
                   bench_star, bench_table2, bench_theorem1, roofline)

    benches = {
        "motivating_example": bench_motivating_example.main,
        "table2": bench_table2.main,
        "theorem1": bench_theorem1.main,
        "latency_qstar": bench_latency_qstar.main,
        "lp_scaling": bench_lp_scaling.main,
        "kernels": bench_kernels.main,
        "engine_throughput": bench_engine_throughput.main,
        "star": bench_star.main,
        "session": bench_session.main,
        "roofline_single": lambda quick: roofline.main(quick, mesh="single"),
        "roofline_multi": lambda quick: roofline.main(quick, mesh="multi"),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    all_claims = {}
    failures = []
    t0 = time.time()
    for name, fn in benches.items():
        try:
            claims = fn(quick) or {}
        except Exception as e:  # keep going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, f"{type(e).__name__}: {e}"))
            continue
        for k, v in claims.items():
            all_claims[f"{name}.{k}"] = v

    print(f"\n=== summary ({time.time()-t0:.1f}s) ===")
    bad = [k for k, v in all_claims.items() if v is False]
    for k, v in sorted(all_claims.items()):
        import numpy as _np
        if not isinstance(v, (bool, _np.bool_)):
            print(f"  --  {k} = {v}")  # informational (counts etc.)
            continue
        print(f"  {'OK ' if v else 'BAD'} {k} = {v}")
    for name, err in failures:
        print(f"  ERR {name}: {err}")
    print(f"{len(all_claims) - len(bad)}/{len(all_claims)} claims OK, "
          f"{len(failures)} bench errors")
    return 1 if (bad or failures) else 0


if __name__ == "__main__":
    sys.exit(main())
