"""Golden-eval campaign as a bench: the paper's §6 claim at scale.

Quick mode runs the smoke tier (256 instances) into
``bench_out/campaign_smoke.{json,md}``; ``--full`` runs the sweep of record
(1296 instances) into ``bench_out/campaign.{json,md}`` — the committed
document ``scripts/check_campaign.py`` gates on.  The split mirrors the CSV
convention: a laptop/CI smoke run must never overwrite the full-scale
numbers of record.

Claims: zero anomalies (the hard invariant), plus the headline counts and
domination rate as informational values.  Throughput lands in the CSV so
the summary can track campaign cost over time.
"""

from __future__ import annotations

import os
import time

from repro.eval import build_document, full_spec, run_campaign, smoke_spec
from repro.eval.report import write_campaign

from .common import OUT_DIR, banner, write_csv


def main(quick: bool = True) -> dict:
    banner("golden-eval campaign (LP vs §3 heuristics)")
    spec = smoke_spec() if quick else full_spec()
    stem = "campaign_smoke" if quick else "campaign"

    t0 = time.time()
    result = run_campaign(spec, progress=lambda m: print(f"  {m}"))
    elapsed = time.time() - t0

    doc = build_document(result)
    write_campaign(doc, os.path.join(OUT_DIR, f"{stem}.json"),
                   os.path.join(OUT_DIR, f"{stem}.md"))

    counts = result.counts()
    rows = [[spec.name, result.n, counts.get("lp-wins", 0),
             counts.get("tie", 0), counts.get("heuristic-infeasible", 0),
             counts.get("lp-fallback", 0), counts.get("anomaly", 0),
             f"{result.domination_rate:.6f}", f"{result.n / elapsed:.1f}"]]
    write_csv(f"{stem}_throughput.csv", rows,
              ["tier", "n", "lp_wins", "tie", "heuristic_infeasible",
               "lp_fallback", "anomaly", "domination_rate", "inst_per_sec"])

    print(f"  {result.n} instances in {elapsed:.1f}s "
          f"({result.n / elapsed:.1f} inst/s): {counts}")
    return {
        "zero_anomalies": len(result.anomalies) == 0,
        "n_instances": result.n,
        "domination_rate": result.domination_rate,
        "lp_wins": counts.get("lp-wins", 0),
        "ties": counts.get("tie", 0),
    }
