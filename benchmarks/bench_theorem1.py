"""Theorem 1 (§5): under the LINEAR cost model, LP(Q+1) <= LP(Q) — any finite
number of installments is suboptimal; the makespan keeps (strictly) improving
with more installments, so the linear model cannot pick a Q.

We verify Q-monotonicity empirically on the §3 example and random instances,
and record the (shrinking) marginal gain per added installment.
"""

from __future__ import annotations

import numpy as np

from repro.core.closed_form import example_instance
from repro.core.instance import random_instance
from repro.core.theory import q_monotonicity

from .common import banner, write_csv


def main(quick: bool = False) -> dict:
    banner("bench_theorem1 (§5, Q-monotonicity under the linear model)")
    qs = [1, 2, 3, 4, 6, 8] if not quick else [1, 2, 3, 4]
    rng = np.random.default_rng(1)
    rows = []
    monotone = strict_somewhere = 0
    cases = [("example_lam_0.5", example_instance(0.5)),
             ("example_lam_1.0", example_instance(1.0))]
    n_rand = 3 if quick else 8
    for k in range(n_rand):
        cases.append((f"random_{k}", random_instance(
            rng, m=5, n_loads=3, comm_to_comp=rng.choice([0.5, 1.0, 5.0]),
            with_latency=False)))
    for name, inst in cases:
        ms = q_monotonicity(inst, qs)
        rows.extend([[name, q, m] for q, m in zip(qs, ms)])
        diffs = np.diff(ms)
        # relative tolerance: HiGHS optimality gap is ~1e-8 of the objective
        tol = 1e-7 * np.maximum(np.abs(np.asarray(ms[:-1])), 1.0)
        monotone += bool((diffs <= tol).all())
        strict_somewhere += bool((diffs < -1e-12).any())
        gain = (ms[0] - ms[-1]) / ms[0] * 100
        print(f"  {name:<18} LP(Q): " + " ".join(f"{m:.6f}" for m in ms)
              + f"  (total gain {gain:.3f}%)")
    write_csv("theorem1.csv", rows, ["case", "q", "lp_makespan"])
    claims = {
        "lp_nonincreasing_in_q": monotone == len(cases),
        "strict_improvement_exists": strict_somewhere > 0,
    }
    for k, v in claims.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
