"""§Perf hillclimb driver: run one (arch × shape) cell under named
ShardingPolicy variants, re-lower, re-analyse, and print the roofline-term
deltas vs the paper-faithful baseline.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell llama3.2-3b:prefill_32k \\
      --variants baseline,last_logit,bf16_logits

Each variant's full record is saved to bench_out/dryrun/ with a tag so the
iterations are reproducible; the EXPERIMENTS.md §Perf log cites these tags.
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json

from repro.config import ShardingPolicy

# named policy variants (the §Perf candidate changes); per-cell sets below
VARIANTS = {
    "baseline": {},
    # --- serving ---
    "last_logit": {"prefill_last_logit_only": True},
    "bf16_logits": {"logits_fp32": False},
    "last+bf16": {"prefill_last_logit_only": True, "logits_fp32": False},
    "noseqshard": {"shard_seq_attn": False, "qkv_feature_shard": False},
    "int8kv": {"kv_cache_dtype": "int8"},
    "sp": {"sp_activations": True},
    "sp+last": {"sp_activations": True, "prefill_last_logit_only": True},
    "sp+last+bf16": {"sp_activations": True, "prefill_last_logit_only": True,
                     "logits_fp32": False},
    "sp+noremat": {"sp_activations": True, "remat": "none"},
    "sp_noq": {"sp_activations": True, "qkv_feature_shard": False},
    "sp_noq_noremat": {"sp_activations": True, "qkv_feature_shard": False,
                       "remat": "none"},
    "sp_noq+last": {"sp_activations": True, "qkv_feature_shard": False,
                    "prefill_last_logit_only": True},
    "chunk4k": {"attn_chunk": 4096},
    "chunk2k": {"attn_chunk": 2048},
    "blockskip": {"attn_block_skip": True},
    "blockskip4k": {"attn_block_skip": True, "attn_chunk": 4096},
    # --- training ---
    "noremat": {"remat": "none"},
    "nofsdp": {"fsdp_params": False},
    "noremat+bf16": {"remat": "none", "logits_fp32": False},
    "noremat+sp": {"remat": "none", "sp_activations": True,
                   "qkv_feature_shard": False},
    "expert_model": {"expert_axis": "model", "expert_ff_axis": "data"},
    "microbatch4": {},  # handled via tcfg below
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    arch, shape = args.cell.split(":")

    from repro.config import TrainConfig
    from repro.launch.dryrun import run_cell

    rows = []
    for name in args.variants.split(","):
        over = VARIANTS[name]
        policy = dataclasses.replace(ShardingPolicy(scan_layers=False), **over)
        tcfg = TrainConfig(microbatches=4) if name == "microbatch4" else TrainConfig()
        rec = run_cell(arch, shape, args.mesh == "multi", policy=policy, tcfg=tcfg,
                       verbose=False)
        fn = f"bench_out/dryrun/{arch}_{shape}_{args.mesh}_hc-{name}.json"
        os.makedirs(os.path.dirname(fn), exist_ok=True)
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] != "ok":
            print(f"{name:<14} FAILED: {rec.get('error')}")
            continue
        rl = rec["roofline"]
        rows.append((name, rl["compute_s"], rl["memory_s"], rl["collective_s"],
                     rec["collective_wire_bytes"], rec["memory"]["output_size_in_bytes"],
                     rec["compile_s"]))
    if not rows:
        return
    base = rows[0]
    print(f"\n{args.cell} ({args.mesh}-pod)  [t in seconds; Δ vs {rows[0][0]}]")
    print(f"{'variant':<14} {'compute':>10} {'mem(xla)':>10} {'collective':>11} "
          f"{'out_bytes':>11} {'compile':>8}")
    for name, c, m, coll, wire, outb, comp in rows:
        print(f"{name:<14} {c:>10.4f} {m:>10.3f} {coll:>11.4f} {outb:>11.3e} {comp:>7.0f}s"
              f"   Δc={100*(c/base[1]-1):+6.1f}% Δm={100*(m/base[2]-1):+6.1f}% "
              f"Δcoll={100*(coll/base[3]-1):+6.1f}%")


if __name__ == "__main__":
    main()
