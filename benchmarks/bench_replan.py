"""Replan latency: warm-started re-solves vs cold solves on single-event
perturbations (the PR-9 online-replanning claim).

Two rungs:

  * **simplex layer** — one packed bucket of star-with-returns instances is
    solved cold, the constraint rows are perturbed by a mild speed drift
    (the ``SpeedObserved`` regime: coefficients move, the row pattern does
    not), and the perturbed batch is re-solved twice: cold (full two-phase)
    and warm (``warm_basis=`` the previous exit basis, basis-seeded entry,
    zero phase-1 pivots on accepted lanes).  The acceptance bar is warm
    >= 3x cold at full scale.  Objectives are asserted equal (rtol 1e-9)
    every rep — a fast wrong answer is not a speedup.
  * **event stream** — end-to-end ``EventStreamReplanner.apply`` latency for
    a run of distinct ``SpeedObserved`` events, warm vs ``warm=False``,
    through separate sessions (every apply a cache miss in both).  Recorded
    informationally: session dispatch + artifact assembly amortize the
    solver win, so the end-to-end ratio is the honest serving number while
    the simplex rung isolates the mechanism.

CSV: bench_out/replan.csv.  The >=3x bar is a full-scale claim only; smoke
runs record the ratios informationally (same convention as bench_hotpath).
"""

from __future__ import annotations

import gc
import time

import numpy as np

from .common import banner, write_csv

B_FULL = 256  # bucket width at full scale (one compiled shape)
B_QUICK = 32
N_EVENTS_FULL = 24  # end-to-end SpeedObserved run length
N_EVENTS_QUICK = 6


def _population(rng, n: int) -> list:
    """Same-shape star instances with returns -> exactly one packed bucket
    (the shape proven in tests/test_scheduling_fuzz.py's warm-start arm)."""
    from repro.core.instance import random_instance

    return [
        random_instance(rng, m=4, n_loads=2, q=2, topology="star",
                        return_ratio=0.25)
        for _ in range(n)
    ]


def _bench_simplex(rng, n: int) -> dict:
    from repro.engine.arena import pack_instances
    from repro.engine.batched_lp import build_lp_bucket
    from repro.engine.batched_simplex import solve_simplex_batched

    insts = _population(rng, n)
    (bucket,) = pack_instances(insts)
    lp = build_lp_bucket(bucket)
    c = np.tile(lp.c, (bucket.B, 1))

    base = solve_simplex_batched(c, lp.A_ub, lp.b_ub, lp.A_eq, lp.b_eq)
    assert (base.status == 0).all(), "cold baseline failed to solve"
    # a single-event perturbation: the speed drift moves coefficients but
    # keeps the row pattern, so the exit basis remains a valid seed
    A_ub2 = lp.A_ub * (1 + 1e-3)

    # warm-up: compile both perturbed paths before timing
    cold0 = solve_simplex_batched(c, A_ub2, lp.b_ub, lp.A_eq, lp.b_eq)
    warm0 = solve_simplex_batched(c, A_ub2, lp.b_ub, lp.A_eq, lp.b_eq,
                                  warm_basis=base.basis)
    accepted = int(warm0.warm_started.sum())
    assert accepted > 0, "no lane accepted the carried basis"
    np.testing.assert_allclose(warm0.objective, cold0.objective,
                               rtol=1e-9, atol=1e-12)

    cold_t, warm_t = [], []
    for _ in range(3):
        gc.collect()
        t0 = time.perf_counter()
        cold = solve_simplex_batched(c, A_ub2, lp.b_ub, lp.A_eq, lp.b_eq)
        cold_t.append(time.perf_counter() - t0)
        gc.collect()
        t0 = time.perf_counter()
        warm = solve_simplex_batched(c, A_ub2, lp.b_ub, lp.A_eq, lp.b_eq,
                                     warm_basis=base.basis)
        warm_t.append(time.perf_counter() - t0)
        np.testing.assert_allclose(warm.objective, cold.objective,
                                   rtol=1e-9, atol=1e-12)
    return {
        "cold": n / sorted(cold_t)[1],
        "warm": n / sorted(warm_t)[1],
        "accepted": accepted,
        "n": n,
    }


def _bench_event_stream(n_events: int) -> dict:
    from repro.api import Policy, Problem, Session
    from repro.runtime.replan import EventStreamReplanner, SpeedObserved

    problem = Problem(
        w=[1.0, 2.0, 1.5, 1.2],
        z=[0.3, 0.2, 0.25],
        v_comm=[1.0, 2.0],
        v_comp=[1.0, 1.5],
        latency=[1e-3, 2e-3, 1.5e-3],
        topology="star",
        return_ratio=0.25,
    )
    policy = Policy(installments=2, backend="batched")
    # distinct w values: every apply is a fresh problem (cache miss) in both
    # runs, so the ratio compares solver work, not cache behaviour
    events = [SpeedObserved(index=1 + (k % 3), w=1.3 + 0.01 * k)
              for k in range(n_events)]

    out = {}
    for label, warm in (("cold", False), ("warm", True)):
        rp = EventStreamReplanner(Session(policy=policy), problem, policy,
                                  warm=warm)
        rp.apply(SpeedObserved(index=1, w=1.29))  # compile the apply path
        gc.collect()
        t0 = time.perf_counter()
        arts = rp.replay(events)
        out[label] = n_events / (time.perf_counter() - t0)
        assert all(a.ok for a in arts)
        rp.close()
    return out


def main(quick: bool = False) -> dict:
    banner("bench_replan (warm-start simplex vs cold / event-stream apply)")
    claims: dict = {}

    n = B_QUICK if quick else B_FULL
    sx = _bench_simplex(np.random.default_rng(17), n)
    speedup = sx["warm"] / sx["cold"]
    print(f"  simplex re-solve ({sx['n']} lanes, {sx['accepted']} warm-accepted): "
          f"cold {sx['cold']:9.0f} inst/s   warm {sx['warm']:9.0f} inst/s "
          f"({speedup:.1f}x)")

    n_ev = N_EVENTS_QUICK if quick else N_EVENTS_FULL
    ev = _bench_event_stream(n_ev)
    ev_ratio = ev["warm"] / ev["cold"]
    print(f"  event-stream apply ({n_ev} SpeedObserved): "
          f"cold {ev['cold']:7.1f} ev/s   warm {ev['warm']:7.1f} ev/s "
          f"({ev_ratio:.2f}x, informational)")

    write_csv(
        "replan.csv",
        [
            ["replan_solve_per_sec", "cold", sx["cold"]],
            ["replan_solve_per_sec", "warm", sx["warm"]],
            ["replan_warm_speedup", "simplex", speedup],
            ["replan_event_per_sec", "cold", ev["cold"]],
            ["replan_event_per_sec", "warm", ev["warm"]],
        ],
        ["metric", "label", "value"],
    )

    claims["warm_accepted_lanes"] = sx["accepted"] > 0
    if quick:
        claims["warm_speedup"] = round(speedup, 1)
        claims["event_stream_ratio"] = round(ev_ratio, 2)
    else:
        claims["warm_3x_cold"] = speedup >= 3.0
    for k, v in claims.items():
        if isinstance(v, bool):
            print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
        else:
            print(f"  CLAIM {k} = {v} (informational at smoke scale)")
    return claims


if __name__ == "__main__":
    main()
