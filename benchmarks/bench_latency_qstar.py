"""§5 beyond the impossibility: with per-message startup latencies K_i (the
affine model the paper prescribes as the fix), a FINITE optimal installment
count Q* exists.  We sweep the latency scale and record Q*(K): as messages get
more expensive, the optimal number of installments falls toward 1.

This is the practical answer to Theorem 1: the linear model says "infinitely
many installments", the affine model picks the deployable Q*.
"""

from __future__ import annotations

import numpy as np

from repro.core.instance import Chain, Instance, Loads, random_instance
from repro.core.theory import optimal_installments

from .common import banner, write_csv


def main(quick: bool = False) -> dict:
    banner("bench_latency_qstar (§5, affine model -> finite Q*)")
    rng = np.random.default_rng(2)
    base = random_instance(rng, m=4, n_loads=2, comm_to_comp=2.0, with_latency=False)
    scales = [0.0, 1e-4, 1e-3, 1e-2, 0.1] if quick else [0.0, 1e-5, 1e-4, 1e-3, 1e-2, 0.05, 0.1, 0.5]
    # express latency relative to the mean single-load transfer time
    t_comm = float(np.mean(base.loads.v_comm) * np.mean(base.chain.z))
    rows = []
    qstars = []
    q_max = 6 if quick else 10
    for s in scales:
        lat = np.full(base.m - 1, s * t_comm)
        inst = Instance(
            Chain(w=base.chain.w, z=base.chain.z, tau=base.chain.tau, latency=lat),
            base.loads, q=1)
        res = optimal_installments(inst, q_max=q_max)
        qstars.append(res.q_star)
        for q, ms in sorted(res.makespans.items()):
            rows.append([s, q, ms, res.q_star])
        print(f"  latency {s:>7.0e} x t_comm: Q* = {res.q_star:>2} "
              f"(makespan {res.makespans[res.q_star]:.6f})")
    write_csv("latency_qstar.csv", rows, ["latency_scale", "q", "lp_makespan", "q_star"])
    claims = {
        # zero latency: more installments keep helping (Theorem 1 regime)
        "q_star_at_cap_when_linear": qstars[0] >= q_max - 1,
        # large latency: single installment optimal
        "q_star_1_when_latency_large": qstars[-1] == 1,
        "q_star_nonincreasing": all(a >= b for a, b in zip(qstars, qstars[1:])),
    }
    for k, v in claims.items():
        print(f"  CLAIM {k}: {'OK' if v else 'VIOLATED'}")
    return claims


if __name__ == "__main__":
    main()
