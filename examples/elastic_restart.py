"""Elastic restart: checkpoint/restart + chain resize, end to end.

Phase 1: train on a 3-stage plan, checkpointing every 2 steps.
Phase 2: stage 1 "fails" — the planner drops it (fusing its links, paper §2
         availability dates tau_i = restore time), the last checkpoint is
         restored, training continues on the 2-stage plan.
Phase 3: a NEW stage joins (elastic scale-up) — replan again, keep training.

Because the synthetic data stream is a pure function of the step index, the
restored run re-sees exactly the batches a failure-free run would have —
asserted below.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.config import ShardingPolicy, TrainConfig, get_arch, smoke_variant
from repro.core.planner import LinkSpec, Planner, StageSpec
from repro.data import batch_load_spec, make_batch
from repro.models import init_params
from repro.runtime import make_train_state, make_train_step
from repro.runtime.ft import FailureEvent, RecoveringChain

CKPT = "/tmp/repro_elastic_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = smoke_variant(get_arch("phi4-mini-3.8b"))
policy = ShardingPolicy(attn_chunk=16)
tcfg = TrainConfig(lr=1e-3, warmup_steps=0, total_steps=30)
B, S = 8, 32

load = batch_load_spec(cfg, B, S)
speed = load.flops_per_sample * B / 0.05
mkstage = lambda i: StageSpec(f"pod{i}", speed / (1 + 0.3 * i))
planner = Planner([mkstage(0), mkstage(1), mkstage(2)],
                  [LinkSpec(load.bytes_per_sample * B / 0.015, 1e-4)] * 2)
chain = RecoveringChain(planner, [load, load], q=1)
print(f"phase 1: 3-stage chain, plan makespan {chain.plan.makespan*1e3:.1f} ms, "
      f"samples {[list(map(int, s)) for s in chain.plan.samples]}")

params = init_params(cfg, policy, seed=0, dtype=jnp.float32)
state = make_train_state(params, tcfg)
step_fn = jax.jit(make_train_step(cfg, policy, tcfg))
mgr = CheckpointManager(CKPT, keep=5)
losses = {}

def run_steps(state, lo, hi):
    for s in range(lo, hi):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, B, S, step=s).items()}
        state, m = step_fn(state, batch)
        losses[s] = float(m["loss"])
        print(f"  step {s}: loss {losses[s]:.4f}")
        mgr.save_async(s, state)
        mgr.wait()
    return state

state = run_steps(state, 0, 4)

print("\nphase 2: stage 1 fails -> drop, fuse links, replan, restore ckpt")
chain.on_failure(FailureEvent(step=4, stage=1, restore_delay=0.5))
print(f"  surviving chain: {chain.stage_names()}, "
      f"new makespan {chain.plan.makespan*1e3:.1f} ms, "
      f"samples {[list(map(int, s)) for s in chain.plan.samples]}")
ls = latest_step(CKPT)
state, _ = restore_checkpoint(CKPT, ls, state)
print(f"  restored checkpoint step {ls}")
# deterministic stream: re-running step ls+1 sees the exact same batch
b_replay = make_batch(cfg, B, S, step=ls + 1)
b_orig = make_batch(cfg, B, S, step=ls + 1)
assert np.array_equal(b_replay["tokens"], b_orig["tokens"]), "stream must be deterministic"
state = run_steps(state, ls + 1, ls + 4)

print("\nphase 3: a new stage joins (elastic scale-up) -> replan")
chain.on_join(StageSpec("pod3-new", speed / 1.1, available_at=0.7),  # joins later
              LinkSpec(load.bytes_per_sample * B / 0.015, 1e-4))
print(f"  chain: {chain.stage_names()}, makespan {chain.plan.makespan*1e3:.1f} ms, "
      f"samples {[list(map(int, s)) for s in chain.plan.samples]}")
state = run_steps(state, max(losses) + 1, max(losses) + 4)

seq = [losses[k] for k in sorted(losses)]
assert seq[-1] < seq[0], f"loss should improve: {seq[0]:.4f} -> {seq[-1]:.4f}"
print(f"\nelastic_restart OK: loss {seq[0]:.4f} -> {seq[-1]:.4f}, "
      f"replans={chain.replans}, log={chain.log}")
