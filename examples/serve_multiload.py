"""Multi-load serving: N inference request batches are the paper's N divisible
loads.  The LP plans how many requests of each batch each chain stage serves
and in how many installments; we compare its (simulated) makespan against the
load-by-load heuristics on the same chain, then actually serve the planned
requests with the real decode loop (CPU smoke model).

Run:  PYTHONPATH=src python examples/serve_multiload.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShardingPolicy, get_arch, smoke_variant
from repro.core.heuristics import multi_inst, simple, single_inst
from repro.core.planner import BatchSpec, LinkSpec, Planner, StageSpec
from repro.data import make_batch
from repro.models import decode_flops_per_token, init_params, prefill
from repro.runtime import make_serve_step

N_BATCHES = 3       # the N loads
BATCH = 6           # requests per batch
PROMPT, GEN = 16, 8

cfg = smoke_variant(get_arch("llama3.2-3b"))
policy = ShardingPolicy(attn_chunk=16)

# --- the chain: 4 heterogeneous stages, scaled so one batch ~ 60ms/stage ---
fl = decode_flops_per_token(cfg, PROMPT) * GEN
speed = fl * BATCH / 0.06
stages = [StageSpec(f"pod{i}", speed / (1 + 0.5 * i)) for i in range(4)]
links = [LinkSpec(bytes_per_sec=4.0 * PROMPT * BATCH / 0.02, startup_sec=1e-4)] * 3
planner = Planner(stages, links)
loads = [BatchSpec(num_samples=BATCH, bytes_per_sample=4.0 * PROMPT,
                   flops_per_sample=fl) for _ in range(N_BATCHES)]

print(f"=== scheduling {N_BATCHES} request batches x {BATCH} requests on a "
      f"4-stage chain ===")
plan = planner.plan(loads, q=2)
inst = planner.to_instance(loads, q=2)
print(f"LP plan makespan: {plan.makespan * 1e3:.2f} ms")
for name, fn in [("SIMPLE", simple), ("SINGLEINST", single_inst),
                 ("MULTIINST", lambda i: multi_inst(i, cap=100))]:
    r = fn(planner.to_instance(loads, q=1))
    rel = r.makespan / plan.makespan if not r.failed else float("inf")
    print(f"  {name:>10}: {r.makespan * 1e3:8.2f} ms  ({rel:5.2f}x LP)"
          + ("  FAILED" if r.failed else ""))
for t, (n, j) in enumerate(plan.cells):
    print(f"  batch {n} installment {j}: requests/stage = "
          f"{[int(x) for x in plan.samples[t]]}")

# --- actually serve the requests (single CPU device plays every stage) ---
print("\n=== executing the plan with the real decode loop ===")
params = init_params(cfg, policy, seed=0, dtype=jnp.float32)
serve_step = jax.jit(make_serve_step(cfg, policy), donate_argnums=(1,))
t0 = time.time()
total_tokens = 0
for n in range(N_BATCHES):
    batch = make_batch(cfg, BATCH, PROMPT, step=n)
    toks = jnp.asarray(batch["tokens"])
    logits, cache, pos = prefill(params, cfg, policy, toks, max_len=PROMPT + GEN)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    outs = []
    for i in range(GEN):
        logits, cache = serve_step(params, cache, nxt, jnp.int32(pos + i))
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs.append(np.asarray(nxt))
    total_tokens += GEN * BATCH
    print(f"  batch {n}: generated {GEN} tokens x {BATCH} requests; "
          f"head of request 0: {np.concatenate(outs, 1)[0, :5].tolist()}")
dt = time.time() - t0
print(f"served {total_tokens} tokens in {dt:.2f}s "
      f"({total_tokens / dt:.1f} tok/s on {jax.default_backend()})")
print("serve_multiload OK")
