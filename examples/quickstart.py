"""Quickstart: the paper's contribution in one page.

1. Build the §3 motivating instance (2 processors, 2 loads, lambda=3/4).
2. Solve it optimally with the Fig. 6 linear program (Q=2 installments) —
   through the solver-backend registry, with any registered backend.
3. Compare against the Wong-Veeravalli-Barlas heuristics it supersedes.
4. Solve a STAR instance (one-port master + heterogeneous workers) with a
   result-return phase through the exact same registry — the constraint
   families are emitted once, topology-dispatched, so every backend
   inherits every scenario (DESIGN.md §6).
5. Use the same planner to schedule training batches for a real (smoke-size)
   model on a heterogeneous 3-stage chain, let `plan_auto_T` pick the
   installment count under a fixed per-installment cost (the practical
   Theorem-1 chooser), and run one training step per plan cell on CPU.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.config import ShardingPolicy, TrainConfig, get_arch, smoke_variant
from repro.core import SolveRequest, available_backends, get_backend
from repro.core.closed_form import example_instance
from repro.core.heuristics import multi_inst, simple, single_inst
from repro.core.planner import BatchSpec, LinkSpec, Planner, StageSpec
from repro.core.solver import solve
from repro.data import batch_load_spec, make_batch
from repro.models import init_params
from repro.runtime import make_train_state, make_train_step

# ---------------------------------------------------------------------- 1+2+3
print("=== the paper's example: 2 identical processors, lambda = 3/4 ===")
inst = example_instance(0.75, q=2)
lp = solve(inst)  # the classic shim: routes through the "auto" backend
print(f"LP (Fig. 6, Q=2 installments): makespan = {lp.makespan:.6f}"
      f"  (paper's hand schedule: 781/653 * 3/4 = {781 / 653 * 0.75:.6f})")

# the same solve, stated as a request against any registered backend
print(f"registered solver backends: {available_backends()}")
report = get_backend("simplex").solve(SolveRequest(instance=inst))
print(f"simplex backend agrees: makespan = {report.makespan:.6f} "
      f"(status={report.status})")
# the fused-kernel engine — what `launch/serve.py --plan-backend pallas`
# serves with; parity with every other backend is fuzz-tested at <= 1e-9
report_pl = get_backend("pallas").solve(SolveRequest(instance=inst))
print(f"pallas backend agrees:  makespan = {report_pl.makespan:.6f} "
      f"(backend={report_pl.backend}, status={report_pl.status})")
for name, fn in [("SIMPLE", simple), ("SINGLEINST", single_inst),
                 ("MULTIINST", lambda i: multi_inst(i, cap=300))]:
    r = fn(example_instance(0.75))
    print(f"{name:>10}: makespan = {r.makespan:.6f}"
          + ("  (FAILED)" if r.failed else ""))
print("gamma (fraction of each load per processor per installment):")
print(np.array_str(lp.schedule.gamma, precision=4, suppress_small=True))

# ------------------------------------------------------------------------- 4
print("\n=== the same registry on a star platform with result return ===")
from repro.core import Instance, Loads, Star, star_single_load_makespan

# a one-port master + 3 heterogeneous workers on a uniform-speed bus;
# return_ratio=0.25 makes every computed fraction ship a quarter of its
# input volume back to the master before the load counts as done
star = Star(w=[0.8, 1.2, 0.6, 1.5], z=[0.3, 0.3, 0.3])
star_inst = Instance(star, Loads(v_comm=[1.0], v_comp=[1.0]), q=1)
star_lp = get_backend("batched").solve(SolveRequest(instance=star_inst))
cf = star_single_load_makespan(star.w, star.z, 1.0, 1.0)
print(f"star (bus) single load: LP makespan = {star_lp.makespan:.6f}, "
      f"closed form = {cf:.6f} (equal on uniform links)")
ret_inst = Instance(star, Loads(v_comm=[1.0], v_comp=[1.0], return_ratio=0.25), q=1)
ret_lp = get_backend("batched").solve(SolveRequest(instance=ret_inst))
print(f"with result return (ratio 0.25): makespan = {ret_lp.makespan:.6f} "
      f"(last return arrives at {float(ret_lp.schedule.ret_end.max()):.6f})")

# ------------------------------------------------------------------------- 5
print("\n=== the same LP scheduling real training batches on a chain ===")
cfg = smoke_variant(get_arch("llama3.2-3b"))
policy = ShardingPolicy(attn_chunk=16)
tcfg = TrainConfig(lr=1e-3, warmup_steps=0, total_steps=10)
B, S = 8, 32
load = batch_load_spec(cfg, B, S)

# a heterogeneous 3-stage chain scaled so one batch ~ 40ms of compute
speed = load.flops_per_sample * B / 0.04
stages = [StageSpec("pod0", speed), StageSpec("pod1", speed / 2),
          StageSpec("pod2", speed / 3)]
links = [LinkSpec(bytes_per_sec=load.bytes_per_sample * B / 0.01, startup_sec=1e-4)] * 2
planner = Planner(stages, links)
# let the cost-aware Theorem-1 sweep pick the installment count: each
# installment is charged a fixed overhead (launch/bookkeeping), so unlike
# the pure linear model the optimum T* is finite
auto = planner.plan_auto_T([load, load], t_max=4, installment_cost=2e-4,
                           backend="serial")
print("auto-T sweep (0.2ms/installment): "
      + ", ".join(f"q={q}: {auto.makespans[q] * 1e3:.2f}ms"
                  for q in sorted(auto.makespans))
      + f" -> T* = {auto.t_star}")
plan = auto.plan
print(f"planned makespan: {plan.makespan * 1e3:.2f} ms "
      f"(T* = {auto.t_star} installments/load)")
for t, (n, j) in enumerate(plan.cells):
    print(f"  load {n}, installment {j}: samples/stage = "
          f"{[int(x) for x in plan.samples[t]]}")

params = init_params(cfg, policy, seed=0, dtype=jnp.float32)
state = make_train_state(params, tcfg)
step = make_train_step(cfg, policy, tcfg)
for i in range(3):
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, B, S, step=i).items()}
    state, metrics = step(state, batch)
    print(f"train step {i}: loss = {float(metrics['loss']):.4f}")
print("quickstart OK")
