"""Quickstart: the paper's contribution through the one front door.

Everything routes through ``repro.api`` — a declarative (Problem, Policy)
pair handed to a Session (DESIGN.md §7):

1. The §3 motivating instance (2 processors, 2 loads, lambda=3/4) solved
   optimally with the Fig. 6 LP on several backends, vs the heuristics it
   supersedes.
2. A STAR platform with a result-return phase through the exact same
   session — plus the versioned PlanArtifact: JSON out, JSON in, replayed
   bit-identically (ship plans between processes).
3. Serving-style traffic: async ``submit()`` tickets coalescing into
   micro-batched engine solves.
4. The same LP scheduling real training batches on a heterogeneous chain,
   with the cost-aware Theorem-1 auto-T* chooser stated as Policy, and one
   training step per plan cell on CPU.

Migration (old call -> new call):

  =====================================  =====================================
  historical entry point                 repro.api front door
  =====================================  =====================================
  solve(inst, backend="b")               session.solve(problem, Policy(
                                             installments=q, backend="b"))
  solve_batch(insts)                     session.solve_bulk(problems)
  Planner.plan(batches, q, backend)      planner.plan(...) (unchanged shim) or
                                         session.solve(planner.to_problem(b),
                                             Policy(installments=q, ...))
  Planner.plan_auto_T(b, t_max, cost)    session.solve(problem, Policy(
                                             auto_t=True, t_max=...,
                                             installment_cost=...))
  PlanService().submit/flush/result      session.submit(...) -> ticket;
                                         ticket.result() / session.flush()
  LPResult / SolveReport                 PlanArtifact (versioned, JSON
                                         round-trippable, with provenance)
  =====================================  =====================================

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.api import PlanArtifact, Policy, Problem, Session
from repro.config import ShardingPolicy, TrainConfig, get_arch, smoke_variant
from repro.core import available_backends
from repro.core.closed_form import example_instance, star_single_load_makespan
from repro.core.heuristics import multi_inst, simple, single_inst
from repro.core.planner import BatchSpec, LinkSpec, Planner, StageSpec
from repro.data import batch_load_spec, make_batch
from repro.models import init_params
from repro.runtime import make_train_state, make_train_step

# one session owns the backend handles, the solution cache, and the submit
# queue — every solve below goes through it
session = Session()

# ------------------------------------------------------------------- 1
print("=== the paper's example: 2 identical processors, lambda = 3/4 ===")
paper = Problem.from_instance(example_instance(0.75))
art = session.solve(paper, Policy(installments=2))  # Fig. 6, Q=2 installments
print(f"LP (Fig. 6, Q=2 installments): makespan = {art.makespan:.6f}"
      f"  (paper's hand schedule: 781/653 * 3/4 = {781 / 653 * 0.75:.6f})")

print(f"registered solver backends: {available_backends()}")
for backend in ("simplex", "pallas"):
    a = session.solve(paper, Policy(installments=2, backend=backend))
    print(f"{backend:>8} backend agrees: makespan = {a.makespan:.6f} "
          f"(served by {a.backend}, status={a.status})")
for name, fn in [("SIMPLE", simple), ("SINGLEINST", single_inst),
                 ("MULTIINST", lambda i: multi_inst(i, cap=300))]:
    r = fn(example_instance(0.75))
    print(f"{name:>10}: makespan = {r.makespan:.6f}"
          + ("  (FAILED)" if r.failed else ""))
print("gamma (fraction of each load per processor per installment):")
print(np.array_str(art.gamma, precision=4, suppress_small=True))

# ------------------------------------------------------------------- 2
print("\n=== a star platform with result return + the shippable artifact ===")
# a one-port master + 3 heterogeneous workers on a uniform-speed bus;
# return_ratio=0.25 makes every computed fraction ship a quarter of its
# input volume back to the master before the load counts as done
star = Problem(topology="star", w=[0.8, 1.2, 0.6, 1.5], z=0.3,
               v_comm=[1.0], v_comp=[1.0])
star_art = session.solve(star, Policy(backend="batched"))
cf = star_single_load_makespan(np.array(star.w), np.array(star.z), 1.0, 1.0)
print(f"star (bus) single load: LP makespan = {star_art.makespan:.6f}, "
      f"closed form = {cf:.6f} (equal on uniform links)")
ret = Problem(topology="star", w=[0.8, 1.2, 0.6, 1.5], z=0.3,
              v_comm=[1.0], v_comp=[1.0], return_ratio=0.25)
ret_art = session.solve(ret, Policy(backend="batched"))
print(f"with result return (ratio 0.25): makespan = {ret_art.makespan:.6f} "
      f"(last return arrives at {float(ret_art.schedule().ret_end.max()):.6f})")

# the artifact is the wire format: JSON out, JSON in, replay — bit-identical
wire = ret_art.to_json()
shipped = PlanArtifact.from_json(wire)
assert shipped.to_json() == wire, "artifact round-trip must be bit-identical"
print(f"artifact v{shipped.version}: {len(wire)} JSON bytes, "
      f"replayed makespan = {shipped.schedule().makespan:.6f}, "
      f"provenance: backend={shipped.backend}, cache_hit={shipped.cache_hit}")

# ------------------------------------------------------------------- 3
print("\n=== serving-style traffic: coalescing async submission ===")
rng = np.random.default_rng(0)
from repro.core.instance import random_instance
bursty = Session(policy=Policy(backend="batched"), max_batch=8)
tickets = [bursty.submit(Problem.from_instance(
    random_instance(rng, m=3, n_loads=2, q=1))) for _ in range(20)]
makespans = [t.result().makespan for t in tickets]
st = bursty.stats()
print(f"20 staggered submits -> {st['flushes']} engine flushes "
      f"(max_batch=8); mean makespan {np.mean(makespans):.3f}s")

# ---------------------------------------------------------------- 3bis
print("\n=== the flight recorder: spans + metrics (DESIGN.md §8) ===")
# session.trace() records spans for everything inside the block; the saved
# file is Chrome trace-event JSON (open in chrome://tracing or Perfetto)
burst_probs = [Problem.from_instance(
    random_instance(rng, m=3, n_loads=2, q=1)) for _ in range(8)]
with bursty.trace() as tr:
    bursty.solve_bulk(burst_probs)
stage_us = {n: tr.total_us(n) for n in
            ("engine.lp_build", "engine.simplex", "engine.replay")}
print(f"traced {len(tr)} spans over {tr.total_us('session.trace')/1e3:.1f}ms: "
      + ", ".join(f"{n.split('.')[1]} {us/1e3:.1f}ms"
                  for n, us in stage_us.items()))
# tr.save("session.trace.json")  # ship it to chrome://tracing

# re-solving the same problems hits the cache: plans re-materialize through
# the batched bucket replay (no per-instance Python, no pivots), and the
# hit artifacts say so — cache_hit + replay-stage seconds (DESIGN.md §9)
bursty.solve_bulk(burst_probs)  # first hit pass compiles the replay rung
hit = bursty.solve_bulk(burst_probs)[0]
print(f"warm re-solve: cache_hit={hit.cache_hit}, backend={hit.backend}, "
      f"bucket B={hit.telemetry['bucket']['B']} replayed in "
      f"{hit.telemetry['stages']['replay_s']*1e3:.2f}ms, "
      f"pivots={hit.telemetry['lp']['pivots_phase1']}"
      f"+{hit.telemetry['lp']['pivots_phase2']}")

# every solve also feeds the process metrics registry (one key schema for
# cache/session/engine/simplex; `serve --metrics-port` exposes it to scrapes)
from repro.obs import get_registry
snap = get_registry().snapshot()
print("metrics: "
      f"engine bulk solves = {snap.get('repro_engine_bulk_solves_total{path=batched}', 0):.0f}, "
      f"cache hits = {snap.get('repro_cache_hits_total', 0):.0f}, "
      f"phase-2 pivots = {snap.get('repro_simplex_pivots_total{path=batched,phase=2}', 0):.0f}")
# and the artifact carries its own telemetry: per-stage seconds + LP stats
tel = tickets[0].result().telemetry
if tel and "lp" in tel:
    print(f"first ticket's telemetry: bucket B={tel['bucket']['B']}, "
          f"pivots={tel['lp']['pivots_phase1']}+{tel['lp']['pivots_phase2']}, "
          f"simplex {tel['stages']['simplex_s']*1e3:.1f}ms")

# ---------------------------------------------------------------- 3ter
print("\n=== live replanning: platform events -> warm-started re-solves ===")
from repro.runtime.replan import EventStreamReplanner, SpeedObserved

# one replanner tracks one evolving problem (the star-with-returns instance
# from section 2) through its own session; each apply() folds the event,
# re-solves, and publishes to the attached subscription (DESIGN.md §11)
live = Session(policy=Policy(backend="batched"))
replanner = EventStreamReplanner(live, ret, Policy(backend="batched"))
sub = replanner.subscription  # or: live.subscribe(problem, policy)
snap = sub.next(timeout=5.0)  # first update: the initial plan snapshot
print(f"  initial plan: makespan = {snap.makespan:.6f}")
for k in range(3):
    # a worker drifts slower: coefficient-only, so the previous exit basis
    # seeds a verify-first warm entry (zero pivots when it certifies)
    replanner.apply(SpeedObserved(index=2, w=0.6 * (1.0 + 0.05 * (k + 1))))
    update = sub.next(timeout=5.0)  # long-poll the plan feed
    prov = update.events[-1]  # {"kind": "replan", ...} provenance event
    print(f"  {prov['trigger']}(w={replanner.problem.w[2]:.3f}): "
          f"makespan = {update.makespan:.6f}, warm={prov['warm']}, "
          f"pivots={prov['pivots_phase1']}+{prov['pivots_phase2']}")
replanner.close()
assert sub.next(timeout=0.1) is None, "closed feed must drain to None"

# ------------------------------------------------------------- 3quater
print("\n=== the planning service: persistent store + HTTP front door ===")
import os
import tempfile

from repro.serve import PlanClient, PlanServer

# a PlanServer is N worker Sessions behind one bounded admission queue;
# store= persists every solved plan to a sqlite file keyed by the problem's
# quantized content hash, so a RESTARTED server (or a sibling process)
# replays instead of re-solving (DESIGN.md §12)
store_path = os.path.join(tempfile.mkdtemp(prefix="quickstart_"),
                          "plans.sqlite")
serve_policy = Policy(backend="batched")
with PlanServer(store=store_path, workers=2, policy=serve_policy,
                port=0) as server:
    client = PlanClient(f"http://localhost:{server.port}")
    served = client.plan(ret)  # the star-with-returns problem over HTTP
    assert served.diff(ret_art) == {}, "served plan must match direct solve"
    print(f"served over HTTP :{server.port}: makespan = "
          f"{served.makespan:.6f} (diff()-clean vs the direct solve), "
          f"healthz = {client.healthz()['status']}")
with PlanServer(store=store_path, workers=1, policy=serve_policy) as restarted:
    warm = restarted.plan(ret)  # a fresh process over the same store file
    print(f"restarted server: cache_hit={warm.cache_hit} "
          f"(store hits = {restarted.cache.store_hits}) — the warm-restart "
          f"win bench_serve gates at >= 5x")

# ------------------------------------------------------------------- 4
print("\n=== the same LP scheduling real training batches on a chain ===")
cfg = smoke_variant(get_arch("llama3.2-3b"))
policy = ShardingPolicy(attn_chunk=16)
tcfg = TrainConfig(lr=1e-3, warmup_steps=0, total_steps=10)
B, S = 8, 32
load = batch_load_spec(cfg, B, S)

# a heterogeneous 3-stage chain scaled so one batch ~ 40ms of compute
speed = load.flops_per_sample * B / 0.04
stages = [StageSpec("pod0", speed), StageSpec("pod1", speed / 2),
          StageSpec("pod2", speed / 3)]
links = [LinkSpec(bytes_per_sec=load.bytes_per_sample * B / 0.01, startup_sec=1e-4)] * 2
planner = Planner(stages, links, session=session)
# the cost-aware Theorem-1 chooser, stated declaratively: each installment
# is charged a fixed overhead, so unlike the pure linear model T* is finite
auto = planner.plan_auto_T([load, load], t_max=4, installment_cost=2e-4,
                           backend="serial")
print("auto-T sweep (0.2ms/installment): "
      + ", ".join(f"q={q}: {auto.makespans[q] * 1e3:.2f}ms"
                  for q in sorted(auto.makespans))
      + f" -> T* = {auto.t_star}")
plan = auto.plan
print(f"planned makespan: {plan.makespan * 1e3:.2f} ms "
      f"(T* = {auto.t_star} installments/load, artifact "
      f"t_star = {plan.artifact.t_star})")
for t, (n, j) in enumerate(plan.cells):
    print(f"  load {n}, installment {j}: samples/stage = "
          f"{[int(x) for x in plan.samples[t]]}")

params = init_params(cfg, policy, seed=0, dtype=jnp.float32)
state = make_train_state(params, tcfg)
step = make_train_step(cfg, policy, tcfg)
for i in range(3):
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, B, S, step=i).items()}
    state, metrics = step(state, batch)
    print(f"train step {i}: loss = {float(metrics['loss']):.4f}")
print("quickstart OK")
