"""End-to-end driver: train a model with the DLT chain runner — the paper's
installment schedule executed with real JAX collectives (shard_map+ppermute)
over a 4-stage device chain, with a mid-run stage failure, checkpoint restore,
LP re-planning, and a straggler slow-down.

This is a thin wrapper over ``repro.launch.train`` (the production driver);
on CPU it forces 4 host devices and the smoke config.  Scale knobs:
``--steps`` (default 40; a few hundred for the long demo) and ``--d-model``
(raise toward ~100M params on real hardware).

Run:  PYTHONPATH=src python examples/train_dlt_chain.py [--steps 200]
"""

import os
import sys

N_STAGES = 4
os.environ.setdefault("XLA_FLAGS", f"--xla_force_host_platform_device_count={N_STAGES}")

from repro.launch import train  # noqa: E402  (after XLA_FLAGS)


def main():
    steps = "40"
    for i, a in enumerate(sys.argv):
        if a == "--steps":
            steps = sys.argv[i + 1]
    ckpt = "/tmp/repro_dlt_chain_ckpt"
    os.system(f"rm -rf {ckpt}")
    train.main([
        "--arch", "llama3.2-3b", "--smoke",
        "--steps", steps,
        "--batch", "8", "--seq", "32",
        "--dlt-chain", str(N_STAGES), "--dlt-q", "2", "--dlt-loads", "2",
        "--ckpt-dir", ckpt, "--save-every", "5",
        "--fail", f"1@step{max(6, int(steps) // 3)}",
        "--straggle", "3@step3x2.0",
        "--metrics-out", "/tmp/repro_dlt_chain_metrics.json",
    ])
    print("train_dlt_chain OK (see /tmp/repro_dlt_chain_metrics.json)")


if __name__ == "__main__":
    main()
