#!/usr/bin/env python
"""Throughput regression gate over bench_out/summary.json.

Validates the summary against its schema (benchmarks.run.validate_summary),
then compares every tier-1 metric in benchmarks/baseline.json against the
summary's metrics section: a metric that dropped more than ``--threshold``
(default 20%) below its baseline fails the gate.  Metrics missing from the
summary fail too — a silently-skipped bench must not read as a pass.

CI runs this twice (DESIGN.md §8): **blocking** against a summary rebuilt
from the committed bench_out CSVs (the full-scale numbers of record, via
``benchmarks.run --summary-only``), then ``--warn-only`` (exit 0, problems
printed) against the live quick-mode smoke numbers, which are noisy on a
shared runner.  Run locally after ``python -m benchmarks.run --full`` for
the same verdict the blocking gate gives.

  PYTHONPATH=src python scripts/check_regression.py [--warn-only]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)  # benchmarks package
sys.path.insert(0, os.path.join(REPO, "src"))


def check(summary: dict, baseline: dict, threshold: float) -> tuple:
    """Returns (problems, report_lines) — problems empty means the gate holds."""
    from benchmarks.run import validate_summary

    problems = list(validate_summary(summary))
    report = []
    got = summary.get("metrics") or {}
    for name, base in sorted((baseline.get("metrics") or {}).items()):
        if name not in got:
            problems.append(f"missing from summary: {name}")
            continue
        val = float(got[name])
        floor = base * (1.0 - threshold)
        delta = (val - base) / base
        line = f"{name}: {val:.1f} vs baseline {base:.1f} ({delta:+.1%})"
        if val < floor:
            problems.append(f"regression: {line}, floor {floor:.1f}")
        else:
            report.append(f"  ok  {line}")
    return problems, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--summary", default=os.path.join(REPO, "bench_out", "summary.json"))
    ap.add_argument("--baseline", default=os.path.join(REPO, "benchmarks", "baseline.json"))
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed fractional drop vs baseline (default 0.20)")
    ap.add_argument("--warn-only", action="store_true",
                    help="print problems but exit 0 (the current CI mode)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.summary):
        print(f"no summary at {args.summary} — run `python -m benchmarks.run` "
              f"(or --summary-only) first")
        return 0 if args.warn_only else 2
    with open(args.summary) as f:
        summary = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    problems, report = check(summary, baseline, args.threshold)
    for line in report:
        print(line)
    if problems:
        for p in problems:
            print(f"  {'WARN' if args.warn_only else 'FAIL'} {p}")
        print(f"{len(problems)} problem(s) vs {args.baseline}"
              + (" (warn-only: not failing the build)" if args.warn_only else ""))
        return 0 if args.warn_only else 1
    print(f"regression gate OK: {len(report)} metric(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
