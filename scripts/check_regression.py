#!/usr/bin/env python
"""Throughput regression gate over bench_out/summary.json.

Validates the summary against its schema (benchmarks.run.validate_summary),
then compares every tier-1 metric in benchmarks/baseline.json against the
summary's metrics section: a metric that dropped more than ``--threshold``
(default 20%) below its baseline fails the gate.  Missing rows fail HARD in
both directions — a baseline row absent from the summary means a bench was
silently skipped, and a ``repro_bench_*`` summary row absent from the
baseline means a new bench is running ungated (its numbers could halve and
nobody would notice).  ``--allow-missing PATTERN`` (repeatable, fnmatch
globs) is the explicit escape hatch for intentionally-new rows that have no
baseline yet; use it for exactly one CI run, then commit the baseline.

CI runs this twice (DESIGN.md §8): **blocking** against a summary rebuilt
from the committed bench_out CSVs (the full-scale numbers of record, via
``benchmarks.run --summary-only``), then ``--warn-only`` (exit 0, problems
printed) against the live quick-mode smoke numbers, which are noisy on a
shared runner.  Run locally after ``python -m benchmarks.run --full`` for
the same verdict the blocking gate gives.

  PYTHONPATH=src python scripts/check_regression.py [--warn-only]
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)  # benchmarks package
sys.path.insert(0, os.path.join(REPO, "src"))

# summary metrics under this prefix are bench rows the gate owns: every one
# must have a baseline row (or an explicit --allow-missing pattern)
_GATED_PREFIX = "repro_bench_"


def _allowed(name: str, allow_missing) -> bool:
    return any(fnmatch.fnmatch(name, pat) for pat in (allow_missing or ()))


def check(summary: dict, baseline: dict, threshold: float,
          allow_missing=()) -> tuple:
    """Returns (problems, report_lines) — problems empty means the gate holds."""
    from benchmarks.run import validate_summary

    problems = list(validate_summary(summary))
    report = []
    got = summary.get("metrics") or {}
    base_metrics = baseline.get("metrics") or {}
    for name, base in sorted(base_metrics.items()):
        if name not in got:
            if _allowed(name, allow_missing):
                report.append(f"  ok  {name}: missing from summary "
                              f"(--allow-missing)")
            else:
                problems.append(f"missing from summary: {name}")
            continue
        val = float(got[name])
        floor = base * (1.0 - threshold)
        delta = (val - base) / base
        line = f"{name}: {val:.1f} vs baseline {base:.1f} ({delta:+.1%})"
        if val < floor:
            problems.append(f"regression: {line}, floor {floor:.1f}")
        else:
            report.append(f"  ok  {line}")
    # the reverse direction: a bench row with no baseline runs ungated —
    # hard-fail so new benches land WITH their floor (escape hatch:
    # --allow-missing for the one run that establishes the number)
    for name in sorted(got):
        if not name.startswith(_GATED_PREFIX) or name in base_metrics:
            continue
        if _allowed(name, allow_missing):
            report.append(f"  ok  {name}: no baseline row (--allow-missing)")
        else:
            problems.append(
                f"ungated bench row: {name} is in the summary but has no "
                f"baseline (add it to benchmarks/baseline.json or pass "
                f"--allow-missing '{name}')")
    return problems, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--summary", default=os.path.join(REPO, "bench_out", "summary.json"))
    ap.add_argument("--baseline", default=os.path.join(REPO, "benchmarks", "baseline.json"))
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed fractional drop vs baseline (default 0.20)")
    ap.add_argument("--warn-only", action="store_true",
                    help="print problems but exit 0 (the current CI mode)")
    ap.add_argument("--allow-missing", action="append", default=[],
                    metavar="PATTERN",
                    help="fnmatch pattern of metric rows allowed to be "
                         "missing (either direction); repeatable — the "
                         "explicit escape hatch for a new row's first run")
    args = ap.parse_args(argv)

    if not os.path.exists(args.summary):
        print(f"no summary at {args.summary} — run `python -m benchmarks.run` "
              f"(or --summary-only) first")
        return 0 if args.warn_only else 2
    with open(args.summary) as f:
        summary = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    problems, report = check(summary, baseline, args.threshold,
                             allow_missing=args.allow_missing)
    for line in report:
        print(line)
    if problems:
        for p in problems:
            print(f"  {'WARN' if args.warn_only else 'FAIL'} {p}")
        print(f"{len(problems)} problem(s) vs {args.baseline}"
              + (" (warn-only: not failing the build)" if args.warn_only else ""))
        return 0 if args.warn_only else 1
    print(f"regression gate OK: {len(report)} metric(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
