"""Inject the generated roofline table into EXPERIMENTS.md (idempotent).

  PYTHONPATH=src python scripts/finalize_experiments.py
"""

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    subprocess.run([sys.executable, "-m", "benchmarks.roofline", "--mesh", "single"],
                   cwd=ROOT, env=env, check=True, capture_output=True)
    md = open(os.path.join(ROOT, "bench_out", "roofline_single.md")).read()
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    exp = open(exp_path).read()
    block = ("<!-- ROOFLINE_TABLE -->\n\n### Single-pod roofline table "
             "(generated; `(auto)` rows = GSPMD-auto fallback records)\n\n"
             + md + "\n<!-- /ROOFLINE_TABLE -->")
    if "<!-- /ROOFLINE_TABLE -->" in exp:
        exp = re.sub(r"<!-- ROOFLINE_TABLE -->.*?<!-- /ROOFLINE_TABLE -->", block,
                     exp, flags=re.S)
    else:
        exp = exp.replace("<!-- ROOFLINE_TABLE -->", block)
    open(exp_path, "w").write(exp)
    print("EXPERIMENTS.md roofline table updated "
          f"({md.count(chr(10)) - 1} rows)")


if __name__ == "__main__":
    main()
