#!/usr/bin/env python
"""Traced smoke solve: the observability acceptance gate (DESIGN.md §8).

Runs a coalescing-session chain population through the batched engine with
the tracer active, then checks:

  1. the exported Chrome trace is valid JSON in trace-event format;
  2. the recorded spans account for >= 90% of the traced wall time, split
     into named stages — so any session-vs-direct throughput gap
     (bench_session's ratio, >= 0.9 since the dispatch slimming) is
     attributable to a named span, not a mystery;
  3. the traced run is a warm-cache solve (the warm-up fills the cache), so
     every instance replays through the batched hit path —
     ``engine.cache_lookup`` must stay under 30% of the traced wall
     (the bulk key-derivation acceptance bar);
  4. (informational) enabled-metrics overhead vs a NullRegistry run — the
     <= 5% budget from the PR-6 acceptance criteria.

Writes bench_out/session.trace.json (open in chrome://tracing / Perfetto).

  PYTHONPATH=src python scripts/traced_smoke.py [--n 64] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def make_problems(n: int):
    import numpy as np

    from repro.api import Problem

    rng = np.random.default_rng(0)
    probs = []
    for _ in range(n):
        m = 3
        probs.append(Problem(
            w=rng.uniform(1.0, 3.0, m).tolist(),
            z=rng.uniform(0.05, 0.3, m - 1).tolist(),
            v_comm=rng.uniform(0.5, 1.5, 2).tolist(),
            v_comp=rng.uniform(0.5, 1.5, 2).tolist(),
        ))
    return probs


def span_accounting(tracer) -> tuple:
    """(wall_us, accounted_us, per-name totals of the gap-relevant spans).

    Wall time is the root ``session.trace`` span.  "Accounted" sums the
    spans that partition the work one level below the dispatch boundary:
    session-side stages (build_requests / make_artifacts / submit) plus the
    engine's internal stages (cache_lookup / pack / lp_build / simplex /
    replay / serial_rescue) plus the dispatch time NOT inside the engine
    (backend call overhead) — i.e. every microsecond lands in exactly one
    named stage.
    """
    wall = tracer.total_us("session.trace")
    t = tracer.total_us
    engine_stages = {
        "engine.cache_lookup": t("engine.cache_lookup"),
        "engine.hit_replay": t("engine.hit_replay"),
        "engine.pack": t("engine.pack"),
        "engine.lp_build": t("engine.lp_build"),
        "engine.simplex": t("engine.simplex"),
        "engine.replay": t("engine.replay"),
        "engine.serial_rescue": t("engine.serial_rescue"),
    }
    # engine time not in a named stage (bucket scatter, certification, ...)
    engine_other = max(0.0, t("engine.solve_bulk") - sum(engine_stages.values()))
    session_stages = {
        "session.build_requests": t("session.build_requests"),
        "session.make_artifacts": t("session.make_artifacts"),
        "session.submit": t("session.submit"),
    }
    dispatch_overhead = max(0.0, t("session.dispatch") - t("engine.solve_bulk"))
    solve_bulk_other = max(0.0, t("session.solve_bulk")
                           - sum(session_stages.values()) - t("session.dispatch"))
    stages = dict(engine_stages)
    stages["engine.other"] = engine_other
    stages.update(session_stages)
    stages["session.dispatch_overhead"] = dispatch_overhead
    stages["session.other"] = solve_bulk_other
    accounted = sum(stages.values())
    return wall, accounted, stages


def validate_chrome_trace(path: str) -> list:
    errs = []
    with open(path) as f:
        d = json.load(f)  # raises on invalid JSON
    ev = d.get("traceEvents")
    if not isinstance(ev, list) or not ev:
        return ["traceEvents: want a non-empty list"]
    for e in ev:
        if e.get("ph") == "X" and not all(k in e for k in ("name", "ts", "dur", "pid", "tid")):
            errs.append(f"malformed complete event: {e}")
    if not any(e.get("ph") == "X" for e in ev):
        errs.append("no complete (ph=X) span events")
    return errs


def metrics_overhead(session_factory, problems, reps: int = 3) -> tuple:
    """Median solve_bulk wall with the live registry vs a NullRegistry."""
    from repro.obs import metrics as om

    def run(registry):
        prev = om.get_registry()
        om.set_registry(registry)
        try:
            s = session_factory()
            s.solve_bulk(problems)  # warm-up: compile + cache fill
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                s.solve_bulk(problems)
                times.append(time.perf_counter() - t0)
            return sorted(times)[len(times) // 2]
        finally:
            om.set_registry(prev)

    t_null = run(om.NullRegistry())
    t_live = run(om.MetricsRegistry())
    return t_live, t_null


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--out", default=os.path.join(REPO, "bench_out", "session.trace.json"))
    ap.add_argument("--min-coverage", type=float, default=0.90)
    ap.add_argument("--max-cache-lookup-frac", type=float, default=0.30,
                    help="ceiling on engine.cache_lookup's share of the "
                         "warm-cache traced wall (bulk key derivation bar)")
    args = ap.parse_args(argv)

    from repro.api import Policy, Session

    problems = make_problems(args.n)

    def fresh():
        return Session(policy=Policy(backend="batched", installments=2))

    session = fresh()
    session.solve_bulk(problems)  # warm-up: compile every bucket shape
    session.solve_bulk(problems)  # ... and the warm-cache replay rungs
    with session.trace() as tr:
        arts = session.solve_bulk(problems)
    bad = [a for a in arts if not a.ok]
    print(f"solved {len(arts)} problems ({len(bad)} not optimal) in "
          f"{tr.total_us('session.trace') / 1e3:.1f}ms traced")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    tr.save(args.out)
    errs = validate_chrome_trace(args.out)
    if errs:
        print(f"FAIL chrome trace invalid: {errs}")
        return 1
    print(f"chrome trace OK: {args.out} ({len(tr)} spans)")

    wall, accounted, stages = span_accounting(tr)
    coverage = accounted / wall if wall else 0.0
    print(f"span coverage: {coverage:.1%} of {wall / 1e3:.1f}ms wall")
    gap = {k: v for k, v in stages.items()
           if not k.startswith(("engine.lp_build", "engine.simplex",
                                "engine.replay", "engine.hit_replay"))}
    for name, us in sorted(stages.items(), key=lambda kv: -kv[1]):
        mark = " <- gap" if name in gap and us == max(gap.values()) else ""
        print(f"  {name:<28} {us / 1e3:8.2f}ms  ({us / wall:6.1%}){mark}")
    dominant = max(gap, key=gap.get)
    print(f"dominant session-vs-direct gap contributor: {dominant} "
          f"({gap[dominant] / wall:.1%} of traced wall)")
    if coverage < args.min_coverage:
        print(f"FAIL span coverage {coverage:.1%} < {args.min_coverage:.0%}")
        return 1

    # the traced solve ran against a warm cache (the warm-up filled it), so
    # key derivation + lookup must be a bounded slice of the hit path
    lookup_frac = stages["engine.cache_lookup"] / wall if wall else 0.0
    if lookup_frac >= args.max_cache_lookup_frac:
        print(f"FAIL engine.cache_lookup is {lookup_frac:.1%} of the "
              f"warm-cache traced wall (budget {args.max_cache_lookup_frac:.0%})")
        return 1
    print(f"engine.cache_lookup {lookup_frac:.1%} of warm-cache wall "
          f"(budget {args.max_cache_lookup_frac:.0%})")

    t_live, t_null = metrics_overhead(fresh, problems)
    over = (t_live - t_null) / t_null if t_null else 0.0
    verdict = "within" if over <= 0.05 else "OVER"
    print(f"metrics overhead: live {t_live * 1e3:.1f}ms vs null {t_null * 1e3:.1f}ms "
          f"({over:+.1%}, {verdict} the 5% budget; informational — single-box timing)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
