#!/usr/bin/env python
"""Served smoke: the planning-service acceptance gate (DESIGN.md §12).

Starts a real `PlanServer` (HTTP on an ephemeral port, sqlite store in a
temp dir), submits a mixed population through `PlanClient`, and checks:

  1. every served artifact is `diff()`-clean against a direct
     `Session.solve` of the same (problem, policy) — the wire format and
     the worker path lose nothing;
  2. `/healthz` reports ok with the configured worker count and `/metrics`
     exposes the serve counters in Prometheus text;
  3. repeated requests are cache hits (workers share one tiered cache) and
     a RESTARTED server over the same store file serves store hits — the
     cross-process warm-restart path;
  4. `close()` drains: the admitted backlog resolves, new submits are
     rejected with `ServerClosed`, and healthz flips to "draining".

Exits non-zero on any violation; prints a one-line summary per check.

  PYTHONPATH=src python scripts/served_smoke.py [--n 12] [--workers 2]
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def make_problems(n: int):
    import numpy as np

    from repro.api import Problem

    rng = np.random.default_rng(7)
    probs = []
    for k in range(n):
        m = 2 + (k % 2)
        probs.append(Problem(
            w=rng.uniform(1.0, 3.0, m).tolist(),
            z=rng.uniform(0.05, 0.3, m - 1).tolist(),
            v_comm=rng.uniform(0.5, 1.5, 2).tolist(),
            v_comp=rng.uniform(0.5, 1.5, 2).tolist(),
        ))
    return probs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args(argv)

    from repro.api import Policy, Session
    from repro.serve import PlanClient, PlanServer, ServerClosed

    policy = Policy(installments=2, backend="batched")
    problems = make_problems(args.n)
    store = os.path.join(tempfile.mkdtemp(prefix="served_smoke_"),
                         "plans.sqlite")
    direct = Session(policy)

    server = PlanServer(store=store, workers=args.workers, policy=policy,
                        port=0)
    try:
        client = PlanClient(f"http://localhost:{server.port}")

        h = client.healthz()
        assert h["status"] == "ok" and h["workers"] == args.workers, h
        print(f"ok  healthz: {h['status']}, {h['workers']} workers, "
              f"queue {h['queue_depth']}/{h['queue_limit']}")

        served = [client.plan(p) for p in problems]
        assert all(a.ok for a in served), [a.status for a in served]
        for a, p in zip(served, problems):
            ref = direct.solve(p)
            d = a.diff(ref)
            assert d == {}, f"served artifact diverged from direct solve: {d}"
        print(f"ok  parity: {len(served)} served artifacts diff()-clean "
              f"vs direct Session.solve")

        again = [client.plan(p) for p in problems]
        assert all(a.cache_hit for a in again), "repeat must hit the shared cache"
        assert all(a.diff(b) == {} for a, b in zip(again, served))
        print(f"ok  shared cache: {len(again)} repeats all cache hits")

        text = client.metrics_text()
        for needle in ("repro_serve_requests_total",
                       "repro_serve_admitted_total"):
            assert needle in text, f"{needle} missing from /metrics"
        print(f"ok  metrics: serve counters exposed "
              f"({len(text.splitlines())} lines)")
    finally:
        server.close()

    assert server.healthz()["status"] == "draining"
    try:
        server.plan(problems[0])
    except ServerClosed:
        print("ok  drain: post-close submits rejected, healthz draining")
    else:
        raise AssertionError("post-close submit must raise ServerClosed")

    restarted = PlanServer(store=store, workers=1, policy=policy)
    try:
        warm = [restarted.plan(p) for p in problems]
        assert all(a.cache_hit for a in warm), "restart must serve store hits"
        assert restarted.cache.store_hits == len(problems), \
            restarted.cache.store_hits
        for a, b in zip(warm, served):
            assert a.diff(b) == {}, "store-hit artifact diverged"
        print(f"ok  warm restart: {restarted.cache.store_hits} store hits, "
              f"all diff()-clean vs the first process")
    finally:
        restarted.close()

    print("served smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
