#!/usr/bin/env python
"""Domination gate over a campaign document (DESIGN.md §10).

Validates ``bench_out/campaign.json`` structurally
(:func:`repro.eval.report.validate_campaign`), then enforces the paper's
invariant against ``benchmarks/campaign_baseline.json``:

* **anomalies must be zero** — a heuristic beating the LP (at the
  heuristic's own installment structure) or an LP failure on a feasible
  instance is always a hard failure, in every mode;
* **the domination rate may not drop** below the baseline's (exact: the
  rate is 1 - anomalies/n, so any anomaly already fails the first check —
  the baseline comparison is the belt to that suspenders, and catches a
  baseline/doc mismatch);
* spec seed + tier recorded in the baseline must match the document, so
  the gate never silently compares different sweeps.

CI runs this twice, mirroring the §9 bench gate: **blocking** against the
committed ``bench_out/campaign.json`` (the full-sweep numbers of record),
then against a live ``--smoke`` run with ``--warn-only-domination`` (the
anomaly check still blocks; the rate comparison warns for one PR while the
smoke tier collects history — flip plan in DESIGN.md §10).

  PYTHONPATH=src python scripts/check_campaign.py [--campaign PATH]
  PYTHONPATH=src python scripts/check_campaign.py --write-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

BASELINE_KEYS = ("schema_version", "name", "seed", "n", "counts",
                 "domination_rate")


def distill(doc: dict) -> dict:
    """The baseline is the campaign's headline, not the whole document."""
    totals = doc["totals"]
    return {
        "schema_version": doc["schema_version"],
        "name": doc["spec"]["name"],
        "seed": doc["spec"]["seed"],
        "n": totals["n"],
        "counts": totals["counts"],
        "domination_rate": totals["domination_rate"],
    }


def check(doc: dict, baseline: dict, *, warn_only_domination: bool = False,
          smoke: bool = False) -> tuple:
    """Returns (problems, warnings, report_lines)."""
    from repro.eval.report import validate_campaign

    problems = [f"campaign document: {e}" for e in validate_campaign(doc)]
    warnings: list = []
    report: list = []
    if problems:
        return problems, warnings, report

    totals = doc["totals"]
    n_anom = totals["counts"]["anomaly"]
    report.append(f"  instances: {totals['n']}  anomalies: {n_anom}  "
                  f"domination_rate: {totals['domination_rate']:.6f}")
    if n_anom > 0:
        for a in doc["anomalies"][:5]:
            problems.append(
                f"anomaly [{(a.get('anomaly') or {}).get('kind', '?')}] at "
                f"{a['cell_id']} index {a['index']} key {a['content_key']}"
            )
        problems.append(f"{n_anom} anomaly(ies) — the domination invariant broke")

    missing = [k for k in BASELINE_KEYS if k not in baseline]
    if missing:
        problems.append(f"baseline missing keys: {missing}")
        return problems, warnings, report

    # the smoke tier compares rates against the full-sweep baseline but not
    # identity (different spec by design); the blocking run compares both
    if not smoke:
        for key in ("schema_version", "name", "seed", "n"):
            doc_val = _ident(doc, key)
            if doc_val != baseline[key]:
                problems.append(
                    f"baseline/document mismatch on {key}: "
                    f"{doc_val!r} != {baseline[key]!r}"
                )

    rate = totals["domination_rate"]
    floor = baseline["domination_rate"]
    line = f"domination_rate {rate:.6f} vs baseline {floor:.6f}"
    if rate < floor:
        (warnings if warn_only_domination else problems).append(
            f"domination rate dropped: {line}"
        )
    else:
        report.append(f"  ok  {line}")
    return problems, warnings, report


def _ident(doc: dict, key: str):
    if key == "schema_version":
        return doc["schema_version"]
    if key in ("name", "seed"):
        return doc["spec"][key]
    return doc["totals"]["n"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--campaign",
                    default=os.path.join(REPO, "bench_out", "campaign.json"))
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "benchmarks",
                                         "campaign_baseline.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="checking a smoke-tier document: skip the "
                         "baseline-identity comparison (tier/seed/n differ "
                         "from the full sweep by design)")
    ap.add_argument("--warn-only-domination", action="store_true",
                    help="domination-rate drift warns instead of failing "
                         "(anomalies always fail)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="distill --campaign into --baseline and exit")
    args = ap.parse_args(argv)

    if not os.path.exists(args.campaign):
        print(f"no campaign document at {args.campaign} — run "
              f"`python -m repro.eval --smoke|--full --out bench_out` first")
        return 2

    from repro.eval.report import load_campaign

    try:
        doc = load_campaign(args.campaign)
    except ValueError as e:
        print(f"FAIL {e}")
        return 1

    if args.write_baseline:
        with open(args.baseline, "w") as f:
            json.dump(distill(doc), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)

    problems, warnings, report = check(
        doc, baseline, warn_only_domination=args.warn_only_domination,
        smoke=args.smoke,
    )
    for line in report:
        print(line)
    for w in warnings:
        print(f"  WARN {w}")
    if problems:
        for p in problems:
            print(f"  FAIL {p}")
        print(f"{len(problems)} problem(s) vs {args.baseline}")
        return 1
    print("campaign gate OK: zero anomalies, domination rate holds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
