#!/usr/bin/env bash
# CI gate: syntax-compile everything, then run the tier-1 suite.
#
# Usage: scripts/ci.sh [extra pytest args...]
#
# Property tests need `hypothesis` (see requirements-dev.txt); without it
# they skip cleanly and the rest of the suite still gates.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== compile-all syntax gate =="
python -m compileall -q src tests benchmarks scripts

echo "== tier-1 tests =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
